//! Line scanner for the project linter: splits Rust source into
//! per-line *code* and *comment* channels so the rules in
//! [`super::rules`] can match tokens without tripping over strings,
//! comments, or char literals.
//!
//! This is deliberately **not** a Rust parser. The rules only need
//! token-shaped evidence (`HashMap`, `.unwrap()`, `Instant::now`), so a
//! small state machine that
//!
//! 1. strips `//` and nested `/* */` comments into a comment channel,
//! 2. blanks the *contents* of string literals to spaces (keeping the
//!    quotes and the length, so `phase: ""` stays distinguishable from
//!    `phase: "opt"`),
//! 3. blanks char literals (so `'"'` cannot open a string and `'{'`
//!    cannot unbalance brace depth), while leaving lifetime ticks
//!    alone,
//! 4. tracks raw strings (`r"…"`, `r#"…"#`, `br"…"`) across lines,
//!
//! is sufficient and keeps the tool dependency-free, in the same
//! spirit as `util::json`. The scanner also extracts the
//! `// lint: allow(<rule>)` escape hatch and the `#[cfg(test)]`
//! boundary (rules do not apply to test code).

/// One source line after scanning.
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// The code channel: comments removed, string/char contents
    /// blanked to spaces (delimiters and length preserved).
    pub code: String,
    /// The comment channel: text of `//` and `/* */` comments on this
    /// line (used for `lint: allow` and `SAFETY:` detection).
    pub comment: String,
    /// Rules suppressed on this line via `// lint: allow(<rules>)`,
    /// either trailing on the line itself or on an immediately
    /// preceding comment-only line. Lower-cased rule ids; `all`
    /// suppresses everything.
    pub allows: Vec<String>,
}

/// A scanned source file.
pub struct SourceFile {
    /// Path with `/` separators (rule scoping is substring-based).
    pub path: String,
    pub lines: Vec<Line>,
    /// Line number of the first `#[cfg(test)]`; lines at or after it
    /// are exempt from all rules. `usize::MAX` when the file has no
    /// test module. (Every module in this tree keeps its test `mod` at
    /// the tail of the file, so first-marker-to-EOF is exact.)
    pub test_from: usize,
}

impl SourceFile {
    /// True when `number` falls inside the trailing test region.
    pub fn is_test_line(&self, number: usize) -> bool {
        number >= self.test_from
    }
}

/// Lexical state carried across lines.
#[derive(Clone, Copy)]
enum Carry {
    Code,
    /// Inside a block comment, at the given nesting depth (Rust block
    /// comments nest).
    Block(u32),
    /// Inside a normal string literal (they may span lines).
    Str,
    /// Inside a raw string opened with this many `#`s.
    Raw(u32),
}

/// Scan a full source text into per-line code/comment channels.
pub fn scan(path: &str, text: &str) -> SourceFile {
    let mut carry = Carry::Code;
    let mut lines = Vec::new();
    let mut pending: Vec<String> = Vec::new();
    let mut test_from = usize::MAX;
    for (i, raw) in text.lines().enumerate() {
        let number = i + 1;
        let (code, comment, next) = clean_line(raw, carry);
        carry = next;
        let mut allows = parse_allows(&comment);
        if code.trim().is_empty() {
            // comment-only (or blank) line: its allows apply to the
            // next line that carries code
            pending.append(&mut allows);
        } else {
            allows.append(&mut pending);
        }
        if test_from == usize::MAX && code.contains("#[cfg(test)]") {
            test_from = number;
        }
        lines.push(Line { number, code, comment, allows });
    }
    SourceFile { path: path.replace('\\', "/"), lines, test_from }
}

/// Process one physical line under the carried lexical state.
/// Returns (code channel, comment channel, state after the line).
fn clean_line(raw: &str, mut state: Carry) -> (String, String, Carry) {
    let ch: Vec<char> = raw.chars().collect();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0;
    while i < ch.len() {
        match state {
            Carry::Block(depth) => {
                if ch[i] == '/' && i + 1 < ch.len() && ch[i + 1] == '*' {
                    state = Carry::Block(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else if ch[i] == '*' && i + 1 < ch.len() && ch[i + 1] == '/' {
                    state = if depth > 1 { Carry::Block(depth - 1) } else { Carry::Code };
                    comment.push_str("*/");
                    i += 2;
                } else {
                    comment.push(ch[i]);
                    i += 1;
                }
            }
            Carry::Raw(hashes) => {
                if ch[i] == '"' && hashes_at(&ch, i + 1) >= hashes {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    i += 1 + hashes as usize;
                    state = Carry::Code;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Carry::Str => {
                if ch[i] == '\\' {
                    // escape: blank both chars (handles \" and \\); a
                    // trailing \ (line continuation) just runs off the
                    // end, leaving Str carried to the next line
                    code.push(' ');
                    if i + 1 < ch.len() {
                        code.push(' ');
                    }
                    i += 2;
                } else if ch[i] == '"' {
                    code.push('"');
                    i += 1;
                    state = Carry::Code;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Carry::Code => {
                let c = ch[i];
                if c == '/' && i + 1 < ch.len() && ch[i + 1] == '/' {
                    comment.extend(&ch[i..]);
                    i = ch.len();
                } else if c == '/' && i + 1 < ch.len() && ch[i + 1] == '*' {
                    state = Carry::Block(1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    i += 1;
                    state = Carry::Str;
                } else if (c == 'r' || c == 'b') && !ends_in_ident(&code) {
                    if let Some((consumed, hashes)) = raw_opener(&ch, i) {
                        for j in 0..consumed {
                            code.push(ch[i + j]);
                        }
                        i += consumed;
                        state = Carry::Raw(hashes);
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime: 'x' / '\n' are
                    // literals (blank the payload); 'a in `&'a T` is a
                    // lifetime (no closing tick) and passes through
                    if i + 1 < ch.len() && ch[i + 1] == '\\' {
                        code.push('\'');
                        i += 2;
                        code.push(' ');
                        code.push(' ');
                        while i < ch.len() && ch[i] != '\'' {
                            code.push(' ');
                            i += 1;
                        }
                        if i < ch.len() {
                            code.push('\'');
                            i += 1;
                        }
                    } else if i + 2 < ch.len() && ch[i + 2] == '\'' {
                        code.push('\'');
                        code.push(' ');
                        code.push('\'');
                        i += 3;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    // a normal string unterminated at EOL spans lines; block comments
    // and raw strings likewise — `state` carries all three
    (code, comment, state)
}

/// Count consecutive `#`s starting at `i`.
fn hashes_at(ch: &[char], i: usize) -> u32 {
    let mut n = 0;
    while (i + n as usize) < ch.len() && ch[i + n as usize] == '#' {
        n += 1;
    }
    n
}

/// True when the code built so far ends in an identifier char — the
/// next `r`/`b` is then part of an identifier (`for`, `ptr`), not a
/// raw-string opener.
fn ends_in_ident(code: &str) -> bool {
    matches!(code.chars().next_back(), Some(c) if c.is_ascii_alphanumeric() || c == '_')
}

/// Detect a raw-string opener (`r"`, `r#"`, `br##"`, …) at position
/// `i`. Returns (chars consumed including the quote, hash count).
fn raw_opener(ch: &[char], i: usize) -> Option<(usize, u32)> {
    let mut j = i;
    if ch[j] == 'b' {
        j += 1;
    }
    if j >= ch.len() || ch[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while j < ch.len() && ch[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < ch.len() && ch[j] == '"' {
        Some((j + 1 - i, hashes))
    } else {
        None
    }
}

/// Extract rule ids from a `lint: allow(r1, r2)` marker in a comment.
fn parse_allows(comment: &str) -> Vec<String> {
    let mut out = Vec::new();
    if let Some(pos) = comment.find("lint:") {
        let after = comment[pos + 5..].trim_start();
        if let Some(body) = after.strip_prefix("allow(") {
            if let Some(end) = body.find(')') {
                for r in body[..end].split(',') {
                    let r = r.trim().to_ascii_lowercase();
                    if !r.is_empty() {
                        out.push(r);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(src: &str) -> Line {
        let mut sf = scan("x.rs", src);
        sf.lines.remove(0)
    }

    #[test]
    fn strings_blank_but_keep_shape() {
        let l = one(r#"let s = "HashMap inside"; s.len()"#);
        assert!(!l.code.contains("HashMap"));
        assert!(l.code.contains(".len()"));
        // length and quotes preserved
        assert_eq!(l.code.len(), r#"let s = "HashMap inside"; s.len()"#.len());
        assert!(l.code.contains(r#""              ""#));
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        let l = one(r#"let s = "a\"b.unwrap()"; ok()"#);
        assert!(!l.code.contains(".unwrap()"));
        assert!(l.code.contains("ok()"));
    }

    #[test]
    fn line_comment_moves_to_comment_channel() {
        let l = one("foo(); // trailing .unwrap() note");
        assert!(!l.code.contains(".unwrap()"));
        assert!(l.comment.contains(".unwrap()"));
    }

    #[test]
    fn nested_block_comment_spans_lines() {
        let sf = scan("x.rs", "a(); /* one /* two */ still */ b();\nc();");
        assert!(sf.lines[0].code.contains("a();"));
        assert!(sf.lines[0].code.contains("b();"));
        assert!(!sf.lines[0].code.contains("two"));
        assert!(sf.lines[1].code.contains("c();"));
    }

    #[test]
    fn block_comment_left_open_carries() {
        let sf = scan("x.rs", "a(); /* open\n.unwrap() inside */ b();");
        assert!(!sf.lines[1].code.contains(".unwrap()"));
        assert!(sf.lines[1].code.contains("b();"));
    }

    #[test]
    fn raw_string_with_hashes() {
        let l = one(r##"let s = r#"quote " and .unwrap() in raw"# ; t()"##);
        assert!(!l.code.contains(".unwrap()"));
        assert!(l.code.contains("t()"));
    }

    #[test]
    fn char_literal_quote_does_not_open_string() {
        let l = one("if c == '\"' { x.unwrap() }");
        assert!(l.code.contains(".unwrap()"), "code after the char literal survives");
    }

    #[test]
    fn char_literal_brace_is_blanked() {
        let l = one("if c == '{' { d += 1; }");
        let opens = l.code.matches('{').count();
        let closes = l.code.matches('}').count();
        assert_eq!(opens, closes, "blanked char literal keeps braces balanced");
    }

    #[test]
    fn lifetime_tick_passes_through() {
        let l = one("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert!(l.code.contains("&'a str"));
    }

    #[test]
    fn trailing_allow_lands_on_its_line() {
        let l = one("danger(); // lint: allow(r3): metrics only");
        assert_eq!(l.allows, vec!["r3".to_string()]);
    }

    #[test]
    fn comment_only_allow_carries_to_next_code_line() {
        let sf = scan("x.rs", "// lint: allow(r1, r2)\n// more prose\ndanger();");
        assert!(sf.lines[0].allows.is_empty());
        assert!(sf.lines[2].allows.contains(&"r1".to_string()));
        assert!(sf.lines[2].allows.contains(&"r2".to_string()));
    }

    #[test]
    fn cfg_test_marks_tail_exempt() {
        let sf = scan("x.rs", "real();\n#[cfg(test)]\nmod tests {}\n");
        assert!(!sf.is_test_line(1));
        assert!(sf.is_test_line(2));
        assert!(sf.is_test_line(3));
    }

    #[test]
    fn allow_inside_string_is_not_parsed() {
        let l = one(r#"let s = "lint: allow(r1)"; danger()"#);
        assert!(l.allows.is_empty(), "allow must come from a comment, not a string");
    }
}
