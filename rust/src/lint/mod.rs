//! `alada lint` — the project's own static-analysis pass.
//!
//! Every headline claim in this tree (sharded == unsharded at any rank
//! count, save@M/resume@N parity, batched == solo decode) rests on
//! invariants the type system cannot see: fixed-order arithmetic only
//! through `tensor::kernels`, no unordered map iteration in hot paths,
//! typed phase-stamped transport errors, no wall-clock in step logic,
//! no mutex guard held across blocking channel calls. The parity
//! suites catch violations *after* they have produced a divergent
//! trajectory; this pass rejects them at review time, with a
//! `file:line` diagnostic, before a test ever runs.
//!
//! The implementation is a hand-rolled line scanner + rule table (see
//! [`scanner`] and [`rules`]) in the same dependency-light spirit as
//! `util::json` — no `syn`, no proc-macro machinery, nothing the
//! container does not already have. That buys a tool that lints the
//! whole tree in milliseconds and that `scripts/check.sh` can gate on
//! between clippy and the tests.
//!
//! Escape hatch: `// lint: allow(<rule>): <reason>` on the offending
//! line (or on a comment line directly above it) suppresses exactly
//! one line. Suppressions are counted and reported so they stay
//! visible.

pub mod rules;
pub mod scanner;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use rules::{Diagnostic, RuleInfo, RULES};

use crate::util::json::Json;

/// Schema version of the `--json` report. Bump only with a matching
/// update to `rust/tests/lint_gate.rs`.
pub const REPORT_VERSION: u64 = 1;

/// Outcome of a lint run over a set of paths.
pub struct Report {
    /// Number of `.rs` files scanned.
    pub checked_files: usize,
    /// Violations, sorted by (file, line, rule).
    pub diagnostics: Vec<Diagnostic>,
    /// Count of would-be violations suppressed by allow comments.
    pub allowed: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Machine-readable report:
    /// `{"version":1,"checked_files":N,"allowed":N,"clean":bool,
    ///   "diagnostics":[{"file","line","rule","message"},…]}`
    pub fn to_json(&self) -> Json {
        let mut top = BTreeMap::new();
        top.insert("version".to_string(), Json::Num(REPORT_VERSION as f64));
        top.insert("checked_files".to_string(), Json::Num(self.checked_files as f64));
        top.insert("allowed".to_string(), Json::Num(self.allowed as f64));
        top.insert("clean".to_string(), Json::Bool(self.clean()));
        let diags = self
            .diagnostics
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("file".to_string(), Json::Str(d.file.clone()));
                m.insert("line".to_string(), Json::Num(d.line as f64));
                m.insert("rule".to_string(), Json::Str(d.rule.to_string()));
                m.insert("message".to_string(), Json::Str(d.message.clone()));
                Json::Obj(m)
            })
            .collect();
        top.insert("diagnostics".to_string(), Json::Arr(diags));
        Json::Obj(top)
    }

    /// Human-readable report: one `file:line: [rule] message` per
    /// violation, then a one-line summary.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{}:{}: [{}] {}\n", d.file, d.line, d.rule, d.message));
        }
        out.push_str(&format!(
            "alada lint: {} files checked, {} violation{}, {} allowed\n",
            self.checked_files,
            self.diagnostics.len(),
            if self.diagnostics.len() == 1 { "" } else { "s" },
            self.allowed
        ));
        out
    }
}

/// Lint every `.rs` file under `paths` (files or directories).
pub fn run(paths: &[String]) -> Result<Report> {
    let mut files = Vec::new();
    for p in paths {
        collect(Path::new(p), &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut diagnostics = Vec::new();
    let mut allowed = 0;
    for f in &files {
        let text =
            std::fs::read_to_string(f).with_context(|| format!("lint: reading {f}"))?;
        let sf = scanner::scan(f, &text);
        let (d, a) = rules::check_file(&sf);
        diagnostics.extend(d);
        allowed += a;
    }
    diagnostics.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(Report { checked_files: files.len(), diagnostics, allowed })
}

/// Recursively gather `.rs` files in deterministic (sorted) order.
/// `target/` and dot-directories are build products, never sources.
fn collect(path: &Path, out: &mut Vec<String>) -> Result<()> {
    let meta = std::fs::metadata(path)
        .with_context(|| format!("lint: no such path {}", path.display()))?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_string_lossy().replace('\\', "/"));
        } else if out.is_empty() {
            // only reject non-.rs when named explicitly at the top
            // level; directory walks just skip them
            bail!("lint: {} is not a .rs file", path.display());
        }
        return Ok(());
    }
    let mut entries: Vec<_> = std::fs::read_dir(path)
        .with_context(|| format!("lint: reading dir {}", path.display()))?
        .collect::<std::io::Result<_>>()
        .with_context(|| format!("lint: reading dir {}", path.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let name = e.file_name();
        let name = name.to_string_lossy();
        if name == "target" || name.starts_with('.') {
            continue;
        }
        let child = e.path();
        if child.is_dir() {
            collect(&child, out)?;
        } else if child.extension().is_some_and(|x| x == "rs") {
            out.push(child.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_schema() {
        let report = Report {
            checked_files: 2,
            diagnostics: vec![Diagnostic {
                file: "rust/src/shard/x.rs".to_string(),
                line: 7,
                rule: "r1",
                message: "msg".to_string(),
            }],
            allowed: 1,
        };
        let s = report.to_json().to_string_compact();
        let parsed = Json::parse(&s).expect("round-trips");
        assert_eq!(parsed.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(parsed.get("checked_files").and_then(Json::as_usize), Some(2));
        assert_eq!(parsed.get("allowed").and_then(Json::as_usize), Some(1));
        assert_eq!(parsed.get("clean").and_then(Json::as_bool), Some(false));
        let diags = parsed.get("diagnostics").and_then(Json::as_arr).expect("arr");
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].get("line").and_then(Json::as_usize), Some(7));
        assert_eq!(diags[0].get("rule").and_then(Json::as_str), Some("r1"));
    }

    #[test]
    fn text_report_has_file_line_rule() {
        let report = Report {
            checked_files: 1,
            diagnostics: vec![Diagnostic {
                file: "a.rs".to_string(),
                line: 3,
                rule: "r4",
                message: "m".to_string(),
            }],
            allowed: 0,
        };
        let text = report.render_text();
        assert!(text.contains("a.rs:3: [r4] m"));
        assert!(text.contains("1 files checked, 1 violation, 0 allowed"));
    }
}
