//! The paper's tensor→matrix reshaping rule (Eq. 12).
//!
//! An order-τ tensor of shape k₁×…×k_τ is viewed as an (m, n) matrix with
//! m = ∏_{i≤j*} kᵢ, n = ∏_{i>j*} kᵢ, where j* minimises |m − n|. The
//! balanced split maximises the memory saving of rank-one factorisation
//! (m + n is smallest when m ≈ n) and, being a row-major view, costs no
//! data movement — mirroring the paper's `Y.view(m, n)` remark.

/// Return `(m, n)` for the balanced split of `shape` (Eq. 12).
///
/// Scalars map to (1, 1), vectors to (1, k): the degenerate splits the
/// optimizers handle with a scalar row factor.
pub fn balanced_split(shape: &[usize]) -> (usize, usize) {
    let total: usize = shape.iter().product::<usize>().max(1);
    let mut best = (0usize, usize::MAX);
    let mut left = 1usize;
    for j in 0..=shape.len() {
        let right = total / left;
        let gap = left.abs_diff(right);
        if gap < best.1 {
            best = (j, gap);
        }
        if j < shape.len() {
            left *= shape[j];
        }
    }
    let m: usize = shape[..best.0].iter().product::<usize>().max(1);
    (m, total / m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrices_stay_put() {
        assert_eq!(balanced_split(&[128, 64]), (128, 64));
    }

    #[test]
    fn vectors_become_rows() {
        assert_eq!(balanced_split(&[100]), (1, 100));
    }

    #[test]
    fn scalars() {
        assert_eq!(balanced_split(&[]), (1, 1));
    }

    #[test]
    fn order3_balances() {
        // 8×4×8 = 256 → gap ties at j = 1 (8|32) and j = 2 (32|8);
        // the first minimiser wins, matching the Python side.
        assert_eq!(balanced_split(&[8, 4, 8]), (8, 32));
        // 2×3×5×7 = 210 → candidates 1|210, 2|105, 6|35, 30|7, 210|1;
        // 30|7 has the smallest gap (23)
        assert_eq!(balanced_split(&[2, 3, 5, 7]), (30, 7));
    }

    #[test]
    fn split_is_sublinear() {
        // The point of Eq. 12: m + n ≪ m·n for higher-order tensors.
        let (m, n) = balanced_split(&[64, 3, 3, 64]);
        assert_eq!(m * n, 64 * 3 * 3 * 64);
        assert!(m + n <= 2 * ((64 * 3 * 3 * 64) as f64).sqrt() as usize + 2);
    }

    #[test]
    fn product_always_preserved() {
        for shape in [vec![5], vec![3, 7], vec![2, 2, 2, 2, 2], vec![17, 1, 4]] {
            let (m, n) = balanced_split(&shape);
            assert_eq!(m * n, shape.iter().product::<usize>());
        }
    }
}
