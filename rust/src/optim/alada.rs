//! Alada — the paper's Algorithm 2, pure-Rust implementation.
//!
//! Per parameter (viewed as an (m, n) matrix by the Eq. 12 balanced
//! split): first moment M (aliasing the gradient slot, §IV-A), rank-one
//! factors p ∈ ℝ^m, q ∈ ℝ^n updated *alternately* (p on even t, q on odd
//! t), the initial-variance scalar v₀, and a shared step counter t.
//!
//! Memory discipline mirrors the paper: the squared momentum V = M̃² and
//! the reconstructed second moment U = p qᵀ are never materialised — the
//! factor projections (V q, Vᵀ p) and the descent division stream over M
//! with on-the-fly squaring and rank-one reconstruction, in single fused
//! passes (also the L3 perf hot path, see benches/bench_optim.rs).
//!
//! # Row-split sharding and the canonical chunked accumulation
//!
//! The descent at row i needs only p[i] and the full q, so the natural
//! way to shard Alada is by *rows*: a rank owning rows [r0, r1) keeps
//! only its slice of p and M, while q and v₀ are replicated across the
//! owners (`AladaView` / `new_sharded`). The even-phase p update is then
//! fully local; the odd-phase q update and the t = 0 ‖G₀‖² need
//! cross-rank sums over rows, supplied by a `Collective`.
//!
//! To keep N-rank training *bit-identical* to the unsharded optimizer
//! regardless of where the rows are cut, every cross-row reduction
//! (Vᵀp, ‖p‖², ‖G₀‖²) is accumulated per fixed row *chunk* (a pure
//! function of m alone — `row_chunk`) and the chunk partials are
//! combined in ascending chunk order. Rank cuts are chunk-aligned, so
//! each chunk partial is computed whole by exactly one rank; the
//! collective's tree only ever adds exact zeros to it (x + 0.0 == x,
//! and the partials are sums of squares, so never -0.0), and the final
//! chunk-order combine is the same float sequence on 1 rank, N ranks,
//! or the unsharded optimizer. Pinned by rust/tests/shard_parity.rs.

use std::ops::Range;

use anyhow::{ensure, Result};

use super::reshape::balanced_split;
use super::{Collective, LocalCollective, Optimizer};
use crate::tensor::{kernels, Tensor};

/// Upper bound on the number of fixed row chunks per balanced-split
/// matrix. Chunks are both the unit of the canonical accumulation above
/// and the partition planner's cut quantum: larger values cut finer
/// (better balance) but grow the odd-step exchange buffer (C·(n+1)
/// floats per split tensor). 128 keeps the GPT2-small planner within
/// ~1.005 of a perfect split while the exchange stays ≪ the gradient.
pub const ROW_CHUNKS: usize = 128;

/// Number of fixed row chunks for an m-row balanced-split matrix.
pub fn n_row_chunks(rows: usize) -> usize {
    rows.min(ROW_CHUNKS).max(1)
}

/// Row range of chunk `c` — a pure function of the FULL row count, never
/// of any partition, which is what makes the accumulation cut-invariant.
pub fn row_chunk(rows: usize, c: usize) -> Range<usize> {
    let chunks = n_row_chunks(rows);
    debug_assert!(c < chunks);
    c * rows / chunks..(c + 1) * rows / chunks
}

/// One tensor's (possibly partial) view for a row-split Alada shard.
#[derive(Clone, Debug)]
pub struct AladaView {
    /// Index into the `params`/`grads` lists handed to `step`.
    pub idx: usize,
    /// FULL tensor shape (the Eq. 12 split applies to this).
    pub shape: Vec<usize>,
    /// Owned rows of the balanced-split matrix; must be chunk-aligned.
    /// May be empty when the tensor is shared but this rank owns none of
    /// it (the rank still participates in the tensor's reductions).
    pub rows: Range<usize>,
    /// True when the tensor's rows are spread over more than one rank:
    /// its q/v₀ reductions then go through the step's `Collective`.
    pub shared: bool,
}

struct Slot {
    /// Index into the `params`/`grads` lists.
    idx: usize,
    /// First-moment window M[row0..row0+rows] (conceptually the gradient
    /// slot — see `aliases_grad_slot`).
    m: Vec<f32>,
    /// Row-factor slice p[row0..row0+rows].
    p: Vec<f32>,
    /// Column factor q — FULL length n, replicated across owner ranks
    /// (identical inputs to its update keep the replicas bit-equal).
    q: Vec<f32>,
    /// v₀ = ‖G₀‖²/(mn) captured at t = 0 (line 9); replicated.
    v0: f32,
    /// First owned row in the full matrix.
    row0: usize,
    /// Owned row count (0 for a pure-participation shared view).
    rows: usize,
    /// Full balanced-split dims.
    full_rows: usize,
    cols: usize,
    shared: bool,
    /// Chunk indices covered by the owned window.
    owned_chunks: Range<usize>,
}

pub struct Alada {
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u32,
    slots: Vec<Slot>,
}

/// Chunk-index range covering `window` (must be chunk-aligned).
fn owned_chunk_range(full_rows: usize, window: &Range<usize>) -> Range<usize> {
    if window.is_empty() {
        return 0..0;
    }
    let chunks = n_row_chunks(full_rows);
    let c0 = (0..chunks)
        .position(|c| row_chunk(full_rows, c).start == window.start)
        .expect("row window must start on a chunk boundary");
    let c1 = (c0..chunks)
        .find(|&c| row_chunk(full_rows, c).end == window.end)
        .expect("row window must end on a chunk boundary");
    c0..c1 + 1
}

impl Alada {
    /// Unsharded optimizer: every slot is a full view of its tensor.
    pub fn new(beta1: f32, beta2: f32, eps: f32, shapes: &[Vec<usize>]) -> Alada {
        let views: Vec<AladaView> = shapes
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let (rows, _) = balanced_split(s);
                AladaView { idx: i, shape: s.clone(), rows: 0..rows, shared: false }
            })
            .collect();
        Alada::new_sharded(beta1, beta2, eps, &views)
    }

    /// One rank's shard: a partial row view per (owned or shared)
    /// tensor. Unshared views must cover their whole tensor — a tensor
    /// owned by exactly one rank is owned entirely.
    pub fn new_sharded(beta1: f32, beta2: f32, eps: f32, views: &[AladaView]) -> Alada {
        let slots = views
            .iter()
            .map(|v| {
                let (full_rows, cols) = balanced_split(&v.shape);
                assert!(v.rows.end <= full_rows, "view rows out of range");
                assert!(
                    v.shared || (v.rows.start == 0 && v.rows.end == full_rows),
                    "an unshared view must cover the whole tensor"
                );
                let rows = v.rows.len();
                Slot {
                    idx: v.idx,
                    m: vec![0.0; rows * cols],
                    p: vec![0.0; rows],
                    q: vec![0.0; if rows > 0 { cols } else { 0 }],
                    v0: 0.0,
                    row0: v.rows.start,
                    rows,
                    full_rows,
                    cols,
                    shared: v.shared,
                    owned_chunks: owned_chunk_range(full_rows, &v.rows),
                }
            })
            .collect();
        Alada { beta1, beta2, eps, t: 0, slots }
    }

    /// True when stepping needs a real cross-rank collective.
    pub fn needs_collective(&self) -> bool {
        self.slots.iter().any(|s| s.shared)
    }

    /// ‖G_t² − p qᵀ‖² — the factorisation error of Prop. 1 (test hook).
    pub fn factorization_error(v: &Tensor, p: &[f32], q: &[f32]) -> f32 {
        let (rows, cols) = (p.len(), q.len());
        assert_eq!(v.len(), rows * cols);
        let vd = v.data();
        let mut err = 0.0f32;
        for i in 0..rows {
            for j in 0..cols {
                let d = vd[i * cols + j] - p[i] * q[j];
                err += d * d;
            }
        }
        err
    }

    /// One update over (possibly partial) views. `params`/`grads` are
    /// indexed by each slot's `idx`; only the owned row windows are read
    /// and written. `coll` carries the cross-rank chunk reductions of
    /// shared slots (a no-op `LocalCollective` is correct when no slot
    /// is shared).
    pub fn step_with(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        lr: f32,
        coll: &mut dyn Collective,
    ) {
        assert_eq!(params.len(), grads.len());
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let t = self.t;
        let bc1 = 1.0 / (1.0 - b1.powi(t as i32 + 1));
        let bc2_pow = b2.powi(t as i32 + 1);
        let bc2_inv = 1.0 / (1.0 - bc2_pow);

        // Lines 5-6: M_{t+1} = β₁ M_t + (1−β₁) G_t over the owned window,
        // bias-corrected on the fly (M̃ never stored; bc1 folds into every
        // read of M).
        for slot in &mut self.slots {
            if slot.rows == 0 {
                continue;
            }
            let g = grads[slot.idx].data();
            let gw = &g[slot.row0 * slot.cols..(slot.row0 + slot.rows) * slot.cols];
            kernels::ema(&mut slot.m, gw, b1, 1.0 - b1);
        }

        // Lines 8-12: t = 0 initialisation from G₀ — ‖G₀‖² accumulated by
        // the canonical per-chunk scheme (shared slots exchange chunk
        // partials; the combine is the same chunk-order float sequence
        // everywhere).
        if t == 0 {
            let mut xbuf: Vec<f32> = Vec::new();
            for slot in &self.slots {
                if !slot.shared {
                    continue;
                }
                let base = xbuf.len();
                xbuf.resize(base + n_row_chunks(slot.full_rows), 0.0);
                let g = grads[slot.idx].data();
                for c in slot.owned_chunks.clone() {
                    let r = row_chunk(slot.full_rows, c);
                    let gw = &g[r.start * slot.cols..r.end * slot.cols];
                    xbuf[base + c] = kernels::dot(gw, gw);
                }
            }
            if !xbuf.is_empty() {
                coll.all_reduce_sum(&mut xbuf);
            }
            let mut off = 0;
            for slot in &mut self.slots {
                let chunks = n_row_chunks(slot.full_rows);
                let sq = if slot.shared {
                    let mut s = 0.0f32;
                    for &v in &xbuf[off..off + chunks] {
                        s += v;
                    }
                    off += chunks;
                    s
                } else {
                    let g = grads[slot.idx].data();
                    let mut s = 0.0f32;
                    for c in 0..chunks {
                        let r = row_chunk(slot.full_rows, c);
                        let gw = &g[r.start * slot.cols..r.end * slot.cols];
                        s += kernels::dot(gw, gw);
                    }
                    s
                };
                if slot.rows == 0 {
                    continue;
                }
                let v0 = sq / (slot.full_rows * slot.cols) as f32;
                slot.v0 = v0;
                let root = v0.sqrt();
                slot.p.iter_mut().for_each(|x| *x = root);
                slot.q.iter_mut().for_each(|x| *x = root);
            }
        }

        // Lines 13-22: alternating factor update + descent.
        //
        // Perf note (§Perf L3, EXPERIMENTS.md): on even steps the descent
        // at row i needs only p_new[i] (q is frozen), so the factor
        // update and the descent fuse into a SINGLE streaming pass over
        // M — row i's projection is computed, then the row is descended
        // immediately while still cache-hot; the pass is also fully
        // local under row-split sharding. Odd steps need the full column
        // reduction Vᵀp (and ‖p‖²) before any descent; those accumulate
        // per fixed row chunk — see the module docs — so they remain two
        // passes plus (when sharded) one small collective. V = (M·bc1)²
        // is always recomputed in-register, never materialised —
        // mirroring the Pallas kernels' HBM discipline. Row bodies are
        // the shared `tensor::kernels` primitives so the autovectorizer
        // lifts them to SIMD.
        if t % 2 == 0 {
            // p_{t+1} = β₂ p + (1−β₂) V q / (‖q‖² + ε); fused descent
            for slot in &mut self.slots {
                if slot.rows == 0 {
                    continue;
                }
                let sub = bc2_pow * slot.v0;
                let qn = kernels::dot(&slot.q, &slot.q) + eps;
                let xd = params[slot.idx].data_mut();
                for i in 0..slot.rows {
                    let mrow = &slot.m[i * slot.cols..(i + 1) * slot.cols];
                    let acc = kernels::sq_dot_scaled(mrow, &slot.q, bc1);
                    let pi = b2 * slot.p[i] + (1.0 - b2) * acc / qn;
                    slot.p[i] = pi;
                    let xrow =
                        &mut xd[(slot.row0 + i) * slot.cols..(slot.row0 + i + 1) * slot.cols];
                    kernels::alada_descent_row(
                        xrow, mrow, &slot.q, pi, bc1, sub, bc2_inv, eps, lr,
                    );
                }
            }
        } else {
            // q_{t+1} = β₂ q + (1−β₂) Vᵀ p / (‖p‖² + ε), both reductions
            // per fixed row chunk. Shared slots stage [C pn-chunks |
            // C·n acc-chunks] into one exchange buffer.
            let mut xbuf: Vec<f32> = Vec::new();
            let mut scratch: Vec<f32> = Vec::new();
            for slot in &self.slots {
                if !slot.shared {
                    continue;
                }
                let chunks = n_row_chunks(slot.full_rows);
                let base = xbuf.len();
                xbuf.resize(base + chunks * (1 + slot.cols), 0.0);
                let (pn_part, acc_part) = xbuf[base..].split_at_mut(chunks);
                for c in slot.owned_chunks.clone() {
                    let r = row_chunk(slot.full_rows, c);
                    let l0 = r.start - slot.row0;
                    let pw = &slot.p[l0..l0 + r.len()];
                    pn_part[c] = kernels::dot(pw, pw);
                    scratch.clear();
                    scratch.resize(slot.cols, 0.0);
                    for (i, &pi) in pw.iter().enumerate() {
                        let mrow = &slot.m[(l0 + i) * slot.cols..(l0 + i + 1) * slot.cols];
                        kernels::sq_axpy_scaled(&mut scratch, mrow, bc1, pi);
                    }
                    acc_part[c * slot.cols..(c + 1) * slot.cols].copy_from_slice(&scratch);
                }
            }
            if !xbuf.is_empty() {
                coll.all_reduce_sum(&mut xbuf);
            }
            let mut off = 0;
            for slot in &mut self.slots {
                let chunks = n_row_chunks(slot.full_rows);
                if slot.rows == 0 {
                    if slot.shared {
                        off += chunks * (1 + slot.cols);
                    }
                    continue;
                }
                let mut acc = vec![0.0f32; slot.cols];
                let mut pn = 0.0f32;
                if slot.shared {
                    let (pn_part, acc_part) =
                        xbuf[off..off + chunks * (1 + slot.cols)].split_at(chunks);
                    for c in 0..chunks {
                        pn += pn_part[c];
                        kernels::axpy(&mut acc, &acc_part[c * slot.cols..(c + 1) * slot.cols], 1.0);
                    }
                    off += chunks * (1 + slot.cols);
                } else {
                    // Unshared ⇒ full window; identical per-chunk
                    // partials + chunk-order combine as the shared path.
                    for c in 0..chunks {
                        let r = row_chunk(slot.full_rows, c);
                        let pw = &slot.p[r.clone()];
                        pn += kernels::dot(pw, pw);
                        scratch.clear();
                        scratch.resize(slot.cols, 0.0);
                        for (i, &pi) in pw.iter().enumerate() {
                            let mrow =
                                &slot.m[(r.start + i) * slot.cols..(r.start + i + 1) * slot.cols];
                            kernels::sq_axpy_scaled(&mut scratch, mrow, bc1, pi);
                        }
                        kernels::axpy(&mut acc, &scratch, 1.0);
                    }
                }
                kernels::factor_ema(&mut slot.q, &acc, b2, pn + eps);
                // descent (separate pass: needs the completed q_new)
                let sub = bc2_pow * slot.v0;
                let xd = params[slot.idx].data_mut();
                for i in 0..slot.rows {
                    let pi = slot.p[i];
                    let mrow = &slot.m[i * slot.cols..(i + 1) * slot.cols];
                    let xrow =
                        &mut xd[(slot.row0 + i) * slot.cols..(slot.row0 + i + 1) * slot.cols];
                    kernels::alada_descent_row(
                        xrow, mrow, &slot.q, pi, bc1, sub, bc2_inv, eps, lr,
                    );
                }
            }
        }
        self.t += 1;
    }
}

impl Optimizer for Alada {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        // hard assert: a silent LocalCollective here would drop other
        // ranks' chunk partials and diverge without any error
        assert!(
            !self.needs_collective(),
            "row-split Alada with cross-rank tensors must step via step_with"
        );
        self.step_with(params, grads, lr, &mut LocalCollective);
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        // Canonical per-view order (optim::state_fields): the owned M
        // window (Elem), the owned p slice (Row), then the replicated q
        // and v₀ (Shared — bit-identical across owners, stored by every
        // owner, restorable from any one). Pure-participation views
        // (rows == 0) keep no state.
        for s in &self.slots {
            if s.rows == 0 {
                continue;
            }
            out.extend_from_slice(&s.m);
            out.extend_from_slice(&s.p);
            out.extend_from_slice(&s.q);
            out.push(s.v0);
        }
    }

    fn import_state(&mut self, _shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()> {
        let total: usize = self
            .slots
            .iter()
            .filter(|s| s.rows > 0)
            .map(|s| s.m.len() + s.p.len() + s.q.len() + 1)
            .sum();
        ensure!(
            data.len() == total,
            "alada state has {} elements, optimizer holds {total}",
            data.len()
        );
        ensure!(step <= u32::MAX as usize, "step counter {step} out of range");
        let mut off = 0;
        for s in &mut self.slots {
            if s.rows == 0 {
                continue;
            }
            s.m.copy_from_slice(&data[off..off + s.m.len()]);
            off += s.m.len();
            s.p.copy_from_slice(&data[off..off + s.p.len()]);
            off += s.p.len();
            s.q.copy_from_slice(&data[off..off + s.q.len()]);
            off += s.q.len();
            s.v0 = data[off];
            off += 1;
        }
        // t > 0 also skips the t = 0 ‖G₀‖² init, whose products (p, q,
        // v₀) the imported state already carries.
        self.t = super::step_u32(step);
        Ok(())
    }

    fn state_overhead_bytes(&self) -> usize {
        // Paper accounting: M aliases the gradient slot; the maintained
        // overhead is p + q + v₀ per parameter = O(m + n) — per rank,
        // the owned p slice plus the replicated q and v₀.
        self.slots
            .iter()
            .map(|s| (s.p.len() + s.q.len() + usize::from(s.rows > 0)) * 4)
            .sum()
    }

    fn aliases_grad_slot(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "alada"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Proposition 1: each alternating update does not increase the
    /// factorisation error ‖V − p qᵀ‖ w.r.t. the *current* V, when the
    /// EMA is replaced by the full projection (β₂ = 0 gives the pure
    /// alternating-minimisation step the proposition analyses).
    #[test]
    fn prop1_projection_reduces_error() {
        let mut rng = Rng::new(7);
        for trial in 0..20 {
            let (m, n) = (5 + (trial % 7), 4 + (trial % 5));
            let v = Tensor::from_fn(&[m, n], |_| {
                let x: f32 = rng.normal();
                x * x + 0.01
            });
            let mut p: Vec<f32> = (0..m).map(|_| rng.range_f32(0.1, 1.0)).collect();
            let mut q: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 1.0)).collect();
            let mut err_prev = Alada::factorization_error(&v, &p, &q);
            for t in 0..10 {
                if t % 2 == 0 {
                    let qn: f32 = q.iter().map(|x| x * x).sum();
                    for i in 0..m {
                        let acc: f32 = (0..n).map(|j| v.at2(i, j) * q[j]).sum();
                        p[i] = acc / qn;
                    }
                } else {
                    let pn: f32 = p.iter().map(|x| x * x).sum();
                    for j in 0..n {
                        let acc: f32 = (0..m).map(|i| v.at2(i, j) * p[i]).sum();
                        q[j] = acc / pn;
                    }
                }
                let err = Alada::factorization_error(&v, &p, &q);
                assert!(
                    err <= err_prev * (1.0 + 1e-5),
                    "error increased at t={t}: {err_prev} -> {err}"
                );
                err_prev = err;
            }
        }
    }

    /// The factors stay strictly positive when gradients are nonzero
    /// (§III: positivity makes p qᵀ a feasible preconditioner). This is
    /// also what keeps the chunk partials nonnegative, so the shared
    /// path's tree zeros can never flip a -0.0.
    #[test]
    fn factors_stay_positive() {
        let shapes = vec![vec![6, 4]];
        let mut opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        let mut rng = Rng::new(3);
        let mut params = vec![Tensor::from_fn(&[6, 4], |_| rng.normal())];
        for _ in 0..25 {
            let g = vec![Tensor::from_fn(&[6, 4], |_| rng.normal() + 0.01)];
            opt.step(&mut params, &g, 1e-3);
            assert!(opt.slots[0].p.iter().all(|&x| x > 0.0));
            assert!(opt.slots[0].q.iter().all(|&x| x > 0.0));
        }
    }

    /// Alternation parity: p changes only on even t, q only on odd t.
    #[test]
    fn alternation_parity() {
        let shapes = vec![vec![4, 3]];
        let mut opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        let mut rng = Rng::new(9);
        let mut params = vec![Tensor::from_fn(&[4, 3], |_| rng.normal())];
        let g = vec![Tensor::from_fn(&[4, 3], |_| rng.normal())];
        opt.step(&mut params, &g, 1e-3); // t=0: p updated (and both initialised)
        let (p1, q1) = (opt.slots[0].p.clone(), opt.slots[0].q.clone());
        opt.step(&mut params, &g, 1e-3); // t=1: q updated, p frozen
        assert_eq!(opt.slots[0].p, p1, "p must not change on odd t");
        assert_ne!(opt.slots[0].q, q1, "q must change on odd t");
        let q2 = opt.slots[0].q.clone();
        opt.step(&mut params, &g, 1e-3); // t=2: p updated, q frozen
        assert_ne!(opt.slots[0].p, p1, "p must change on even t");
        assert_eq!(opt.slots[0].q, q2, "q must not change on even t");
    }

    /// Overhead is O(m + n), not O(mn).
    #[test]
    fn sublinear_overhead() {
        let shapes = vec![vec![1000, 800]];
        let opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        assert_eq!(opt.state_overhead_bytes(), (1000 + 800 + 1) * 4);
    }

    /// Tensors route through the Eq. 12 split.
    #[test]
    fn tensor_param_is_split() {
        let shapes = vec![vec![4, 3, 8]]; // 96 elems → split 12 × 8
        let opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        assert_eq!(opt.slots[0].rows * opt.slots[0].cols, 96);
        assert_eq!(opt.slots[0].p.len() + opt.slots[0].q.len(), 12 + 8);
    }

    /// Chunk geometry: boundaries cover [0, rows) contiguously and are a
    /// function of the full row count only.
    #[test]
    fn row_chunks_tile_the_rows() {
        for rows in [1usize, 2, 7, 128, 129, 1000, 50257] {
            let chunks = n_row_chunks(rows);
            assert!(chunks <= ROW_CHUNKS && chunks >= 1);
            let mut next = 0;
            for c in 0..chunks {
                let r = row_chunk(rows, c);
                assert_eq!(r.start, next, "rows={rows} c={c}");
                assert!(!r.is_empty(), "rows={rows} c={c}");
                next = r.end;
            }
            assert_eq!(next, rows);
        }
    }

    /// Row-split shards over the real channel-mesh collective reproduce
    /// the unsharded optimizer bit-for-bit, for cuts at every chunk
    /// boundary split point. (The Partition-driven, multi-tensor version
    /// of this contract lives in optim/sharded.rs and
    /// rust/tests/shard_parity.rs.)
    #[test]
    fn partial_views_match_full_view_bit_for_bit() {
        use crate::optim::testutil::MeshColl;
        use crate::shard::mesh;

        let shape = vec![23usize, 5];
        let (m, _) = balanced_split(&shape);
        let chunks = n_row_chunks(m); // 23 rows → 23 single-row chunks
        let mut rng = Rng::new(41);
        let params0 = vec![Tensor::from_fn(&shape, |_| rng.normal())];
        let grads: Vec<Vec<Tensor>> = (0..6)
            .map(|_| vec![Tensor::from_fn(&shape, |_| rng.normal() * 0.3)])
            .collect();

        // Reference: unsharded.
        let mut full = Alada::new(0.9, 0.9, 1e-16, std::slice::from_ref(&shape));
        let mut pf = params0.clone();
        for g in &grads {
            full.step(&mut pf, g, 1e-2);
        }

        for ranks in [2usize, 3, 4] {
            // rank r owns chunks [r·C/ranks, (r+1)·C/ranks)
            let bound = |r: usize| {
                let c = r * chunks / ranks;
                if c == chunks {
                    m
                } else {
                    row_chunk(m, c).start
                }
            };
            let outs: Vec<Vec<Tensor>> = std::thread::scope(|s| {
                let handles: Vec<_> = mesh(ranks)
                    .expect("mesh")
                    .into_iter()
                    .enumerate()
                    .map(|(r, comm)| {
                        let shape = shape.clone();
                        let mut ps = params0.clone();
                        let grads = &grads;
                        s.spawn(move || {
                            let v = AladaView {
                                idx: 0,
                                shape,
                                rows: bound(r)..bound(r + 1),
                                shared: true,
                            };
                            let mut shard =
                                Alada::new_sharded(0.9, 0.9, 1e-16, std::slice::from_ref(&v));
                            let mut coll = MeshColl(comm);
                            for g in grads {
                                shard.step_with(&mut ps, g, 1e-2, &mut coll);
                            }
                            ps
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
            });
            // stitch owned rows together and compare bitwise
            let cols = params0[0].len() / m;
            let mut stitched = params0[0].clone();
            for (r, out) in outs.iter().enumerate() {
                let (r0, r1) = (bound(r), bound(r + 1));
                stitched.data_mut()[r0 * cols..r1 * cols]
                    .copy_from_slice(&out[0].data()[r0 * cols..r1 * cols]);
            }
            for (a, b) in stitched.data().iter().zip(pf[0].data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "ranks={ranks}");
            }
        }
    }
}
