//! Alada — the paper's Algorithm 2, pure-Rust implementation.
//!
//! Per parameter (viewed as an (m, n) matrix by the Eq. 12 balanced
//! split): first moment M (aliasing the gradient slot, §IV-A), rank-one
//! factors p ∈ ℝ^m, q ∈ ℝ^n updated *alternately* (p on even t, q on odd
//! t), the initial-variance scalar v₀, and a shared step counter t.
//!
//! Memory discipline mirrors the paper: the squared momentum V = M̃² and
//! the reconstructed second moment U = p qᵀ are never materialised — the
//! factor projections (V q, Vᵀ p) and the descent division stream over M
//! with on-the-fly squaring and rank-one reconstruction, in single fused
//! passes (also the L3 perf hot path, see benches/bench_optim.rs).

use super::reshape::balanced_split;
use super::Optimizer;
use crate::tensor::{kernels, Tensor};

struct Slot {
    /// First moment M_t (stored at the parameter's own shape; conceptually
    /// the gradient slot — see `aliases_grad_slot`).
    m: Tensor,
    /// Row factor p (length = balanced-split m).
    p: Vec<f32>,
    /// Column factor q (length = balanced-split n).
    q: Vec<f32>,
    /// v₀ = ‖G₀‖²/(mn) captured at t = 0 (line 9).
    v0: f32,
    rows: usize,
    cols: usize,
}

pub struct Alada {
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u32,
    slots: Vec<Slot>,
}

impl Alada {
    pub fn new(beta1: f32, beta2: f32, eps: f32, shapes: &[Vec<usize>]) -> Alada {
        let slots = shapes
            .iter()
            .map(|s| {
                let (rows, cols) = balanced_split(s);
                Slot {
                    m: Tensor::zeros(s),
                    p: vec![0.0; rows],
                    q: vec![0.0; cols],
                    v0: 0.0,
                    rows,
                    cols,
                }
            })
            .collect();
        Alada { beta1, beta2, eps, t: 0, slots }
    }

    /// ‖G_t² − p qᵀ‖² — the factorisation error of Prop. 1 (test hook).
    pub fn factorization_error(v: &Tensor, p: &[f32], q: &[f32]) -> f32 {
        let (rows, cols) = (p.len(), q.len());
        assert_eq!(v.len(), rows * cols);
        let vd = v.data();
        let mut err = 0.0f32;
        for i in 0..rows {
            for j in 0..cols {
                let d = vd[i * cols + j] - p[i] * q[j];
                err += d * d;
            }
        }
        err
    }
}

impl Optimizer for Alada {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let t = self.t;
        let bc1 = 1.0 / (1.0 - b1.powi(t as i32 + 1));
        let bc2_pow = b2.powi(t as i32 + 1);
        let bc2_inv = 1.0 / (1.0 - bc2_pow);

        for (slot, (x, g)) in self.slots.iter_mut().zip(params.iter_mut().zip(grads)) {
            let (rows, cols) = (slot.rows, slot.cols);

            // Lines 5-6: M_{t+1} = β₁ M_t + (1−β₁) G_t, bias-corrected on
            // the fly (M̃ never stored; bc1 folds into every read of M).
            slot.m.ema_inplace(g, b1, 1.0 - b1);
            let md = slot.m.data();

            // Lines 8-12: t = 0 initialisation from G₀.
            if t == 0 {
                let v0 = g.sq_norm() / (rows * cols) as f32;
                slot.v0 = v0;
                let root = v0.sqrt();
                slot.p.iter_mut().for_each(|x| *x = root);
                slot.q.iter_mut().for_each(|x| *x = root);
            }

            // Lines 13-22: alternating factor update + descent.
            //
            // Perf note (§Perf L3, EXPERIMENTS.md): on even steps the
            // descent at row i needs only p_new[i] (q is frozen), so the
            // factor update and the descent fuse into a SINGLE streaming
            // pass over M — row i's projection is computed, then the row
            // is descended immediately while still cache-hot. Odd steps
            // need the full column reduction Vᵀp before any descent, so
            // they remain two passes. V = (M·bc1)² is always recomputed
            // in-register, never materialised — mirroring the Pallas
            // kernels' HBM discipline. Row bodies are the shared
            // `tensor::kernels` primitives so the autovectorizer lifts
            // them to SIMD.
            let sub = bc2_pow * slot.v0;
            let xd = x.data_mut();
            if t % 2 == 0 {
                // p_{t+1} = β₂ p + (1−β₂) V q / (‖q‖² + ε); fused descent
                let qn = kernels::dot(&slot.q, &slot.q) + eps;
                for i in 0..rows {
                    let mrow = &md[i * cols..(i + 1) * cols];
                    let acc = kernels::sq_dot_scaled(mrow, &slot.q, bc1);
                    let pi = b2 * slot.p[i] + (1.0 - b2) * acc / qn;
                    slot.p[i] = pi;
                    let xrow = &mut xd[i * cols..(i + 1) * cols];
                    kernels::alada_descent_row(xrow, mrow, &slot.q, pi, bc1, sub, bc2_inv, eps, lr);
                }
            } else {
                // q_{t+1} = β₂ q + (1−β₂) Vᵀ p / (‖p‖² + ε)
                let pn = kernels::dot(&slot.p, &slot.p) + eps;
                let mut acc = vec![0.0f32; cols];
                for i in 0..rows {
                    kernels::sq_axpy_scaled(&mut acc, &md[i * cols..(i + 1) * cols], bc1, slot.p[i]);
                }
                kernels::factor_ema(&mut slot.q, &acc, b2, pn);
                // descent (separate pass: needs the completed q_new)
                for i in 0..rows {
                    let pi = slot.p[i];
                    let mrow = &md[i * cols..(i + 1) * cols];
                    let xrow = &mut xd[i * cols..(i + 1) * cols];
                    kernels::alada_descent_row(xrow, mrow, &slot.q, pi, bc1, sub, bc2_inv, eps, lr);
                }
            }
        }
        self.t += 1;
    }

    fn state_overhead_bytes(&self) -> usize {
        // Paper accounting: M aliases the gradient slot; the maintained
        // overhead is p + q + v₀ per parameter = O(m + n).
        self.slots.iter().map(|s| (s.p.len() + s.q.len() + 1) * 4).sum()
    }

    fn aliases_grad_slot(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "alada"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Proposition 1: each alternating update does not increase the
    /// factorisation error ‖V − p qᵀ‖ w.r.t. the *current* V, when the
    /// EMA is replaced by the full projection (β₂ = 0 gives the pure
    /// alternating-minimisation step the proposition analyses).
    #[test]
    fn prop1_projection_reduces_error() {
        let mut rng = Rng::new(7);
        for trial in 0..20 {
            let (m, n) = (5 + (trial % 7), 4 + (trial % 5));
            let v = Tensor::from_fn(&[m, n], |_| {
                let x: f32 = rng.normal();
                x * x + 0.01
            });
            let mut p: Vec<f32> = (0..m).map(|_| rng.range_f32(0.1, 1.0)).collect();
            let mut q: Vec<f32> = (0..n).map(|_| rng.range_f32(0.1, 1.0)).collect();
            let mut err_prev = Alada::factorization_error(&v, &p, &q);
            for t in 0..10 {
                if t % 2 == 0 {
                    let qn: f32 = q.iter().map(|x| x * x).sum();
                    for i in 0..m {
                        let acc: f32 = (0..n).map(|j| v.at2(i, j) * q[j]).sum();
                        p[i] = acc / qn;
                    }
                } else {
                    let pn: f32 = p.iter().map(|x| x * x).sum();
                    for j in 0..n {
                        let acc: f32 = (0..m).map(|i| v.at2(i, j) * p[i]).sum();
                        q[j] = acc / pn;
                    }
                }
                let err = Alada::factorization_error(&v, &p, &q);
                assert!(
                    err <= err_prev * (1.0 + 1e-5),
                    "error increased at t={t}: {err_prev} -> {err}"
                );
                err_prev = err;
            }
        }
    }

    /// The factors stay strictly positive when gradients are nonzero
    /// (§III: positivity makes p qᵀ a feasible preconditioner).
    #[test]
    fn factors_stay_positive() {
        let shapes = vec![vec![6, 4]];
        let mut opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        let mut rng = Rng::new(3);
        let mut params = vec![Tensor::from_fn(&[6, 4], |_| rng.normal())];
        for _ in 0..25 {
            let g = vec![Tensor::from_fn(&[6, 4], |_| rng.normal() + 0.01)];
            opt.step(&mut params, &g, 1e-3);
            assert!(opt.slots[0].p.iter().all(|&x| x > 0.0));
            assert!(opt.slots[0].q.iter().all(|&x| x > 0.0));
        }
    }

    /// Alternation parity: p changes only on even t, q only on odd t.
    #[test]
    fn alternation_parity() {
        let shapes = vec![vec![4, 3]];
        let mut opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        let mut rng = Rng::new(9);
        let mut params = vec![Tensor::from_fn(&[4, 3], |_| rng.normal())];
        let g = vec![Tensor::from_fn(&[4, 3], |_| rng.normal())];
        opt.step(&mut params, &g, 1e-3); // t=0: p updated (and both initialised)
        let (p1, q1) = (opt.slots[0].p.clone(), opt.slots[0].q.clone());
        opt.step(&mut params, &g, 1e-3); // t=1: q updated, p frozen
        assert_eq!(opt.slots[0].p, p1, "p must not change on odd t");
        assert_ne!(opt.slots[0].q, q1, "q must change on odd t");
        let q2 = opt.slots[0].q.clone();
        opt.step(&mut params, &g, 1e-3); // t=2: p updated, q frozen
        assert_ne!(opt.slots[0].p, p1, "p must change on even t");
        assert_eq!(opt.slots[0].q, q2, "q must not change on even t");
    }

    /// Overhead is O(m + n), not O(mn).
    #[test]
    fn sublinear_overhead() {
        let shapes = vec![vec![1000, 800]];
        let opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        assert_eq!(opt.state_overhead_bytes(), (1000 + 800 + 1) * 4);
    }

    /// Tensors route through the Eq. 12 split.
    #[test]
    fn tensor_param_is_split() {
        let shapes = vec![vec![4, 3, 8]]; // 96 elems → split 12 × 8
        let opt = Alada::new(0.9, 0.9, 1e-16, &shapes);
        assert_eq!(opt.slots[0].rows * opt.slots[0].cols, 96);
        assert_eq!(opt.slots[0].p.len() + opt.slots[0].q.len(), 12 + 8);
    }
}
