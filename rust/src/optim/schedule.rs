//! Step-size schedules.
//!
//! The paper equips every algorithm with the diminishing scheme
//! η_t = η₀·(1 − t/T) (§VI-A)¹ and analyses the Theorem-1 schedule
//! η_t = η·(1 − β₁^{t+1}) (Eq. 16). Both are here, plus the constant and
//! warmup-cosine schedules a framework user expects.
//!
//! ¹ The paper's text prints η_t = η₀/(1 − t/T), which *grows* without
//! bound and diverges at t = T; every experiment description ("diminishing
//! step-size scheme") implies the decaying form, so we implement
//! η₀·(1 − t/T) and keep the literal form available as `PaperLiteral` for
//! the ablation that documents the discrepancy (see DESIGN.md).

/// A step-size schedule: maps iteration t (0-based) to η_t.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    Constant { eta0: f32 },
    /// η₀·(1 − t/T): the paper's diminishing scheme as intended.
    Diminishing { eta0: f32, total: usize },
    /// η₀/(1 − t/T): the formula as literally printed (diverges at T).
    PaperLiteral { eta0: f32, total: usize },
    /// η·(1 − β₁^{t+1}): Theorem 1, Eq. (16).
    Theorem1 { eta: f32, beta1: f32 },
    /// Linear warmup to η₀ over `warmup` steps then cosine decay to
    /// `floor`·η₀ at `total`.
    WarmupCosine { eta0: f32, warmup: usize, total: usize, floor: f32 },
}

impl Schedule {
    pub fn at(&self, t: usize) -> f32 {
        match *self {
            Schedule::Constant { eta0 } => eta0,
            Schedule::Diminishing { eta0, total } => {
                let frac = t as f32 / total.max(1) as f32;
                eta0 * (1.0 - frac).max(1.0 / total.max(1) as f32)
            }
            Schedule::PaperLiteral { eta0, total } => {
                let frac = (t as f32 / total.max(1) as f32).min(0.999_999);
                eta0 / (1.0 - frac)
            }
            Schedule::Theorem1 { eta, beta1 } => eta * (1.0 - beta1.powi(t as i32 + 1)),
            Schedule::WarmupCosine { eta0, warmup, total, floor } => {
                if t < warmup {
                    eta0 * (t + 1) as f32 / warmup.max(1) as f32
                } else {
                    let span = (total.saturating_sub(warmup)).max(1) as f32;
                    let frac = ((t - warmup) as f32 / span).min(1.0);
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI * frac).cos());
                    eta0 * (floor + (1.0 - floor) * cos)
                }
            }
        }
    }

    /// Parse "const:1e-3", "dim:1e-3:1000", "thm1:1e-3:0.9",
    /// "cos:1e-3:100:1000" (CLI / config syntax).
    pub fn parse(s: &str) -> Result<Schedule, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let f = |x: &str| x.parse::<f32>().map_err(|_| format!("bad number {x:?} in schedule {s:?}"));
        let u = |x: &str| x.parse::<usize>().map_err(|_| format!("bad int {x:?} in schedule {s:?}"));
        match parts.as_slice() {
            ["const", eta] => Ok(Schedule::Constant { eta0: f(eta)? }),
            ["dim", eta, total] => Ok(Schedule::Diminishing { eta0: f(eta)?, total: u(total)? }),
            ["lit", eta, total] => Ok(Schedule::PaperLiteral { eta0: f(eta)?, total: u(total)? }),
            ["thm1", eta, b1] => Ok(Schedule::Theorem1 { eta: f(eta)?, beta1: f(b1)? }),
            ["cos", eta, warmup, total] => Ok(Schedule::WarmupCosine {
                eta0: f(eta)?,
                warmup: u(warmup)?,
                total: u(total)?,
                floor: 0.1,
            }),
            _ => Err(format!("unknown schedule {s:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diminishing_decays_to_near_zero() {
        let s = Schedule::Diminishing { eta0: 1.0, total: 100 };
        assert_eq!(s.at(0), 1.0);
        assert!(s.at(50) < s.at(10));
        assert!(s.at(99) > 0.0 && s.at(99) < 0.02);
    }

    #[test]
    fn theorem1_approaches_eta() {
        let s = Schedule::Theorem1 { eta: 2.0, beta1: 0.9 };
        assert!((s.at(0) - 0.2).abs() < 1e-6);
        assert!((s.at(200) - 2.0).abs() < 1e-4);
    }

    #[test]
    fn warmup_then_decay() {
        let s = Schedule::WarmupCosine { eta0: 1.0, warmup: 10, total: 100, floor: 0.1 };
        assert!(s.at(0) < s.at(9));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(99) < 0.2);
    }

    #[test]
    fn parse_round_trip() {
        assert_eq!(Schedule::parse("const:0.5").unwrap(), Schedule::Constant { eta0: 0.5 });
        assert_eq!(
            Schedule::parse("dim:0.1:50").unwrap(),
            Schedule::Diminishing { eta0: 0.1, total: 50 }
        );
        assert!(Schedule::parse("bogus").is_err());
        assert!(Schedule::parse("dim:x:50").is_err());
    }

    #[test]
    fn paper_literal_grows() {
        // documents the printed-formula discrepancy
        let s = Schedule::PaperLiteral { eta0: 1.0, total: 100 };
        assert!(s.at(90) > s.at(0));
    }
}
