//! AdaGrad (Duchi et al. 2011): accumulated squared gradients, mn state.

use anyhow::{ensure, Result};

use super::Optimizer;
use crate::tensor::Tensor;

pub struct AdaGrad {
    eps: f32,
    accum: Vec<Tensor>,
}

impl AdaGrad {
    pub fn new(eps: f32, shapes: &[Vec<usize>]) -> AdaGrad {
        AdaGrad { eps, accum: shapes.iter().map(|s| Tensor::zeros(s)).collect() }
    }
}

impl Optimizer for AdaGrad {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        for ((p, g), a) in params.iter_mut().zip(grads).zip(self.accum.iter_mut()) {
            a.zip_inplace(g, |acc, gi| acc + gi * gi);
            let eps = self.eps;
            for ((x, &gi), &ai) in p.data_mut().iter_mut().zip(g.data()).zip(a.data()) {
                *x -= lr * gi / (ai.sqrt() + eps);
            }
        }
    }

    fn state_overhead_bytes(&self) -> usize {
        self.accum.iter().map(|t| t.len() * 4).sum()
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        for t in &self.accum {
            out.extend_from_slice(t.data());
        }
    }

    fn import_state(&mut self, _shapes: &[Vec<usize>], data: &[f32], _step: usize) -> Result<()> {
        let total: usize = self.accum.iter().map(|t| t.len()).sum();
        ensure!(
            data.len() == total,
            "adagrad state has {} elements, optimizer holds {total}",
            data.len()
        );
        let mut off = 0;
        for t in &mut self.accum {
            let n = t.len();
            t.data_mut().copy_from_slice(&data[off..off + n]);
            off += n;
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "adagrad"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_shrinks_over_time() {
        let shapes = vec![vec![1]];
        let mut opt = AdaGrad::new(1e-8, &shapes);
        let mut params = vec![Tensor::zeros(&[1])];
        let grads = vec![Tensor::full(&[1], 1.0)];
        opt.step(&mut params, &grads, 1.0);
        let d1 = -params[0].data()[0];
        let before = params[0].data()[0];
        opt.step(&mut params, &grads, 1.0);
        let d2 = before - params[0].data()[0];
        assert!(d2 < d1, "adagrad step should shrink: {d1} vs {d2}");
    }
}
