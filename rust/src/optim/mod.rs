//! Pure-Rust optimizer implementations.
//!
//! This is the CPU-side mirror of the in-graph (JAX) optimizers: it powers
//! the theory experiments (Thm. 1 / Cor. 1-2 on synthetic objectives), the
//! Prop. 1 property tests, the Table-IV memory accounting, and the L3
//! micro-benchmarks. The paper's comparators (Adam, Adafactor) and the
//! related-work family (SGD, AdaGrad, SM3, CAME) are all here so every
//! ablation runs against real implementations, not stubs.
//!
//! Contract: `step` consumes the gradient list for one iteration and
//! updates parameters in place. `lr` comes from a `schedule::Schedule`
//! owned by the caller — optimizers are schedule-free, like the paper's
//! setup where one external η_t scheme is shared by all algorithms.

pub mod adafactor;
pub mod adagrad;
pub mod adam;
pub mod alada;
pub mod came;
pub mod reshape;
pub mod schedule;
pub mod sgd;
pub mod sharded;
pub mod sm3;

pub use adafactor::Adafactor;
pub use adagrad::AdaGrad;
pub use adam::Adam;
pub use alada::Alada;
pub use came::Came;
pub use schedule::Schedule;
pub use sgd::Sgd;
pub use sharded::ShardedOptimizer;
pub use sm3::Sm3;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Cross-rank reduction hook for partitioned optimizers.
///
/// Row-split sharding leaves some per-tensor reductions (Alada's Vᵀp
/// column projection, ‖p‖², ‖G₀‖²) spread over ranks; the optimizer
/// hands the per-chunk partials to this hook and gets back the
/// elementwise sum over all ranks. Every rank must call with an
/// identically laid-out buffer, the same number of times per step, and
/// every rank receives the identical sum — the shard engine backs this
/// with its fixed binomial tree over whichever transport carries the
/// run (in-process channels or TCP; the tree lives above the transport,
/// so the backend cannot change the result), making it deterministic;
/// the non-contributing ranks' zeros are exact (x + 0.0 == x).
pub trait Collective {
    fn all_reduce_sum(&mut self, buf: &mut [f32]);
}

/// Single-process collective: the sum over one rank is the identity.
pub struct LocalCollective;

impl Collective for LocalCollective {
    fn all_reduce_sum(&mut self, _buf: &mut [f32]) {}
}

/// How finely an optimizer's state can be partitioned across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionGranularity {
    /// State couples the whole tensor (factored column statistics that
    /// would need their own cross-rank reductions): ranks must own whole
    /// tensors.
    Tensor,
    /// State separates along balanced-split rows: ranks may own row
    /// ranges of a tensor (elementwise state, or Alada's partial view
    /// with the q-reduction collective).
    Row,
}

/// Partition granularity supported by optimizer `name`. Unknown names
/// report `Tensor` (the conservative choice); `by_name` rejects them.
pub fn partition_granularity(name: &str) -> PartitionGranularity {
    match name {
        "sgd" | "sgdm" | "adagrad" | "adam" | "alada" => PartitionGranularity::Row,
        _ => PartitionGranularity::Tensor,
    }
}

/// The paper's Alada defaults (§VI-A) — single source for `by_name` and
/// the row-split shard constructor.
pub(crate) const ALADA_DEFAULTS: (f32, f32, f32) = (0.9, 0.9, 1e-16);

/// A stochastic optimizer over a list of tensors.
pub trait Optimizer {
    /// Apply one update. `grads[i]` matches `params[i]` in shape.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32);

    /// Bytes of optimizer state maintained *across* iterations, using the
    /// paper's accounting (footnote 1): temporaries freed within a step
    /// don't count; the gradient slot itself doesn't count. For Alada the
    /// first moment lives in the gradient slot (paper §IV-A / Listing 1),
    /// so it is excluded here and `aliases_grad_slot` reports it.
    fn state_overhead_bytes(&self) -> usize;

    /// True if the optimizer stores its first moment in the gradient slot
    /// (changes how the memory model attributes the mn buffer).
    fn aliases_grad_slot(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// Build an optimizer by name with the paper's default hyper-parameters
/// (§VI-A). `shapes` pre-sizes the per-parameter state. Unknown names are
/// an error (the CLI turns it into a usage message), not a panic.
pub fn by_name(name: &str, shapes: &[Vec<usize>]) -> Result<Box<dyn Optimizer + Send>> {
    Ok(match name {
        "sgd" => Box::new(Sgd::new(0.0)),
        "sgdm" => Box::new(Sgd::new(0.9)),
        "adagrad" => Box::new(AdaGrad::new(1e-8, shapes)),
        "adam" => Box::new(Adam::new(0.9, 0.999, 1e-8, shapes)),
        "adafactor" => Box::new(Adafactor::new(0.999, 1e-8, shapes)),
        "alada" => {
            let (b1, b2, eps) = ALADA_DEFAULTS;
            Box::new(Alada::new(b1, b2, eps, shapes))
        }
        "sm3" => Box::new(Sm3::new(1e-8, shapes)),
        "came" => Box::new(Came::new(0.9, 0.999, 0.9995, 1e-8, shapes)),
        other => bail!("unknown optimizer {other:?} (known: {ALL:?})"),
    })
}

/// All optimizer names known to `by_name` (ablation sweeps iterate this).
pub const ALL: &[&str] = &["sgd", "sgdm", "adagrad", "adam", "adafactor", "alada", "sm3", "came"];

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    /// `Collective` backed by one rank's mesh endpoint (any transport) —
    /// the unit-test adapter for the row-split optimizer paths (the
    /// engine's production adapters live in shard/engine.rs).
    pub struct MeshColl<T: crate::shard::Transport = crate::shard::InProc>(
        pub crate::shard::Comm<T>,
    );

    impl<T: crate::shard::Transport> Collective for MeshColl<T> {
        fn all_reduce_sum(&mut self, buf: &mut [f32]) {
            self.0.all_reduce_sum(buf, 256);
        }
    }

    /// Random parameter/gradient fixture.
    pub fn fixture(shapes: &[Vec<usize>], seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
        let mut rng = Rng::new(seed);
        let params = shapes
            .iter()
            .map(|s| Tensor::from_fn(s, |_| rng.normal()))
            .collect();
        let grads = shapes
            .iter()
            .map(|s| Tensor::from_fn(s, |_| rng.normal() * 0.1))
            .collect();
        (params, grads)
    }

    /// Every optimizer must move parameters and keep them finite.
    pub fn check_step_sanity(name: &str) {
        let shapes = vec![vec![13, 7], vec![5], vec![3, 4, 2]];
        let (mut params, grads) = fixture(&shapes, 42);
        let before = params.clone();
        let mut opt = by_name(name, &shapes).expect("known optimizer");
        for _ in 0..5 {
            opt.step(&mut params, &grads, 1e-2);
        }
        let mut moved = 0;
        for (p, b) in params.iter().zip(&before) {
            for (&x, &y) in p.data().iter().zip(b.data()) {
                assert!(x.is_finite(), "{name}: non-finite parameter");
                if (x - y).abs() > 1e-8 {
                    moved += 1;
                }
            }
        }
        assert!(moved > 0, "{name}: parameters did not move");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_optimizers_step_sanely() {
        for name in ALL {
            testutil::check_step_sanity(name);
        }
    }

    #[test]
    fn unknown_name_errors_with_the_known_list() {
        let err = by_name("adamw", &[vec![4, 4]]).unwrap_err().to_string();
        assert!(err.contains("unknown optimizer"), "{err}");
        assert!(err.contains("alada"), "should list known names: {err}");
    }

    #[test]
    fn overhead_ordering_matches_paper() {
        // Table IV's story: Adam overhead 2mn ≫ Adafactor/Alada O(m+n).
        let shapes = vec![vec![512, 384]];
        let adam = by_name("adam", &shapes).unwrap();
        let adafactor = by_name("adafactor", &shapes).unwrap();
        let alada = by_name("alada", &shapes).unwrap();
        assert_eq!(adam.state_overhead_bytes(), 2 * 512 * 384 * 4);
        assert!(adafactor.state_overhead_bytes() < adam.state_overhead_bytes() / 100);
        assert!(alada.state_overhead_bytes() < adam.state_overhead_bytes() / 100);
        assert!(alada.aliases_grad_slot());
    }
}
