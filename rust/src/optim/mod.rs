//! Pure-Rust optimizer implementations.
//!
//! This is the CPU-side mirror of the in-graph (JAX) optimizers: it powers
//! the theory experiments (Thm. 1 / Cor. 1-2 on synthetic objectives), the
//! Prop. 1 property tests, the Table-IV memory accounting, and the L3
//! micro-benchmarks. The paper's comparators (Adam, Adafactor) and the
//! related-work family (SGD, AdaGrad, SM3, CAME) are all here so every
//! ablation runs against real implementations, not stubs.
//!
//! Contract: `step` consumes the gradient list for one iteration and
//! updates parameters in place. `lr` comes from a `schedule::Schedule`
//! owned by the caller — optimizers are schedule-free, like the paper's
//! setup where one external η_t scheme is shared by all algorithms.

pub mod adafactor;
pub mod adagrad;
pub mod adam;
pub mod alada;
pub mod came;
pub mod guard;
pub mod reshape;
pub mod schedule;
pub mod sgd;
pub mod sharded;
pub mod sm3;

pub use adafactor::Adafactor;
pub use adagrad::AdaGrad;
pub use adam::Adam;
pub use alada::Alada;
pub use came::Came;
pub use guard::Guard;
pub use schedule::Schedule;
pub use sgd::Sgd;
pub use sharded::ShardedOptimizer;
pub use sm3::Sm3;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Cross-rank reduction hook for partitioned optimizers.
///
/// Row-split sharding leaves some per-tensor reductions (Alada's Vᵀp
/// column projection, ‖p‖², ‖G₀‖²) spread over ranks; the optimizer
/// hands the per-chunk partials to this hook and gets back the
/// elementwise sum over all ranks. Every rank must call with an
/// identically laid-out buffer, the same number of times per step, and
/// every rank receives the identical sum — the shard engine backs this
/// with its fixed binomial tree over whichever transport carries the
/// run (in-process channels or TCP; the tree lives above the transport,
/// so the backend cannot change the result), making it deterministic;
/// the non-contributing ranks' zeros are exact (x + 0.0 == x).
pub trait Collective {
    fn all_reduce_sum(&mut self, buf: &mut [f32]);

    /// True once a reduction has failed (a peer died mid-collective).
    /// The optimizer math stays infallible: a failing adapter latches
    /// the error, turns later reductions into no-ops, and the engine
    /// checks this probe after the step to abort with the real,
    /// phase-stamped transport error. The step's output is garbage once
    /// this is set — callers must not commit it anywhere.
    fn failed(&self) -> bool {
        false
    }
}

/// Single-process collective: the sum over one rank is the identity.
pub struct LocalCollective;

impl Collective for LocalCollective {
    fn all_reduce_sum(&mut self, _buf: &mut [f32]) {}
}

/// How finely an optimizer's state can be partitioned across ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionGranularity {
    /// State couples the whole tensor (factored column statistics that
    /// would need their own cross-rank reductions): ranks must own whole
    /// tensors.
    Tensor,
    /// State separates along balanced-split rows: ranks may own row
    /// ranges of a tensor (elementwise state, or Alada's partial view
    /// with the q-reduction collective).
    Row,
}

/// Partition granularity supported by optimizer `name`. Unknown names
/// report `Tensor` (the conservative choice); `by_name` rejects them.
pub fn partition_granularity(name: &str) -> PartitionGranularity {
    match name {
        "sgd" | "sgdm" | "adagrad" | "adam" | "alada" => PartitionGranularity::Row,
        _ => PartitionGranularity::Tensor,
    }
}

/// Domain of one per-tensor state field of a row-split optimizer — the
/// unit the elastic checkpoint reshard planner cuts state at. A rank
/// owning balanced-split rows `[r0, r1)` of a tensor holds, per field:
/// `Elem` → the `(r1−r0)·cols` covered elements, `Row` → the `r1−r0`
/// covered rows, `SharedCols`/`SharedScalar` → a full replicated copy
/// (bit-identical across owners, so a restore may take any one).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateField {
    /// One f32 per parameter element (row-major over the split matrix).
    Elem,
    /// One f32 per balanced-split row.
    Row,
    /// `cols` f32s replicated across every owner of the tensor.
    SharedCols,
    /// One f32 replicated across every owner of the tensor.
    SharedScalar,
}

/// Per-tensor persistent-state fields of row-split optimizer `name`, in
/// the canonical `export_state` order. Tensor-aligned optimizers report
/// an empty schema — their per-tensor state is an opaque chunk of
/// [`tensor_state_elems`] that only ever moves whole.
pub fn state_fields(name: &str) -> &'static [StateField] {
    match name {
        "sgd" => &[],
        // one momentum / accumulator value per element
        "sgdm" | "adagrad" => &[StateField::Elem],
        // first and second moment, interleaved per tensor: [m_t, u_t]
        "adam" => &[StateField::Elem, StateField::Elem],
        // M window, p slice, replicated q, replicated v₀ (alada.rs)
        "alada" => &[
            StateField::Elem,
            StateField::Row,
            StateField::SharedCols,
            StateField::SharedScalar,
        ],
        _ => &[],
    }
}

/// Persistent-state elements optimizer `name` keeps for one FULL tensor
/// of `shape` — the per-tensor section length of the canonical state
/// layout (and the whole-tensor chunk the reshard planner moves for
/// tensor-aligned optimizers). Mirrors each optimizer's allocation
/// exactly; pinned against `export_state` lengths in the tests below.
pub fn tensor_state_elems(name: &str, shape: &[usize]) -> usize {
    let elems = shape.iter().product::<usize>().max(1);
    let (rows, cols) = reshape::balanced_split(shape);
    match name {
        "sgd" => 0,
        "sgdm" | "adagrad" => elems,
        "adam" => 2 * elems,
        "alada" => elems + rows + cols + 1,
        // factored only when both dims are ≥ 2 (adafactor.rs)
        "adafactor" => {
            if rows >= 2 && cols >= 2 {
                rows + cols
            } else {
                elems
            }
        }
        // full first moment + factored second moment + instability
        "came" => elems + 2 * (rows + cols),
        "sm3" => rows + cols,
        _ => 0,
    }
}

/// The paper's Alada defaults (§VI-A) — single source for `by_name` and
/// the row-split shard constructor.
pub(crate) const ALADA_DEFAULTS: (f32, f32, f32) = (0.9, 0.9, 1e-16);

/// A stochastic optimizer over a list of tensors.
pub trait Optimizer {
    /// Apply one update. `grads[i]` matches `params[i]` in shape.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32);

    /// Bytes of optimizer state maintained *across* iterations, using the
    /// paper's accounting (footnote 1): temporaries freed within a step
    /// don't count; the gradient slot itself doesn't count. For Alada the
    /// first moment lives in the gradient slot (paper §IV-A / Listing 1),
    /// so it is excluded here and `aliases_grad_slot` reports it.
    fn state_overhead_bytes(&self) -> usize;

    /// True if the optimizer stores its first moment in the gradient slot
    /// (changes how the memory model attributes the mn buffer).
    fn aliases_grad_slot(&self) -> bool {
        false
    }

    /// Append the persistent state to `out` as flat f32s in the
    /// canonical layout: per tensor (in construction order), each field
    /// in [`state_fields`] order — [`tensor_state_elems`] elements per
    /// tensor. Lazily-allocated state that does not exist yet (SGD-m
    /// before its first step) may be omitted; callers that need the
    /// canonical length pad with zeros, the semantic initial value
    /// (`ShardedOptimizer::export_state` does). The step counter is NOT
    /// part of the blob — `import_state` restores it from `step`.
    fn export_state(&self, out: &mut Vec<f32>);

    /// Restore state produced by `export_state` on an identically
    /// configured optimizer; `step` restores the internal step counter
    /// (the number of completed updates). `shapes` re-supplies the
    /// parameter shapes for state that is built lazily. Errors on a
    /// length mismatch — never panics on untrusted input.
    fn import_state(&mut self, shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()>;

    fn name(&self) -> &'static str;
}

/// Build an optimizer by name with the paper's default hyper-parameters
/// (§VI-A). `shapes` pre-sizes the per-parameter state. Unknown names are
/// an error (the CLI turns it into a usage message), not a panic.
pub fn by_name(name: &str, shapes: &[Vec<usize>]) -> Result<Box<dyn Optimizer + Send>> {
    Ok(match name {
        "sgd" => Box::new(Sgd::new(0.0)),
        "sgdm" => Box::new(Sgd::new(0.9)),
        "adagrad" => Box::new(AdaGrad::new(1e-8, shapes)),
        "adam" => Box::new(Adam::new(0.9, 0.999, 1e-8, shapes)),
        "adafactor" => Box::new(Adafactor::new(0.999, 1e-8, shapes)),
        "alada" => {
            let (b1, b2, eps) = ALADA_DEFAULTS;
            Box::new(Alada::new(b1, b2, eps, shapes))
        }
        "sm3" => Box::new(Sm3::new(1e-8, shapes)),
        "came" => Box::new(Came::new(0.9, 0.999, 0.9995, 1e-8, shapes)),
        other => bail!("unknown optimizer {other:?} (known: {ALL:?})"),
    })
}

/// All optimizer names known to `by_name` (ablation sweeps iterate this).
pub const ALL: &[&str] = &["sgd", "sgdm", "adagrad", "adam", "adafactor", "alada", "sm3", "came"];

/// Boxed optimizers are optimizers — lets the composable wrappers
/// (`Guard`) sit above whatever `by_name` built without re-boxing.
impl<O: Optimizer + ?Sized> Optimizer for Box<O> {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        (**self).step(params, grads, lr)
    }

    fn state_overhead_bytes(&self) -> usize {
        (**self).state_overhead_bytes()
    }

    fn aliases_grad_slot(&self) -> bool {
        (**self).aliases_grad_slot()
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        (**self).export_state(out)
    }

    fn import_state(&mut self, shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()> {
        (**self).import_state(shapes, data, step)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }
}

/// Error unless every element of `what` is finite. The scan is the fused
/// [`kernels::all_finite`](crate::tensor::kernels::all_finite) pass (one
/// multiply-add per element, no branches); the diagnostic census runs
/// only on the failure path. This is the shared sentinel behind the
/// shard engine's per-step gradient/loss checks and the parity suites'
/// sanity assertions.
pub fn check_finite(what: &str, xs: &[f32]) -> Result<()> {
    if crate::tensor::kernels::all_finite(xs) {
        return Ok(());
    }
    let (mut nans, mut infs, mut first) = (0usize, 0usize, usize::MAX);
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            nans += 1;
        } else if x.is_infinite() {
            infs += 1;
        } else {
            continue;
        }
        first = first.min(i);
    }
    bail!(
        "{what}: {nans} NaN + {infs} Inf among {} elements (first at index {first})",
        xs.len()
    )
}

/// The one sanctioned `usize -> u32` step-counter narrowing. Every
/// optimizer stamps `self.t` from the engine's `usize` step; funneling
/// the cast through here keeps lint rule r6 (no narrowing `as` in
/// update math) meaningful — a new cast site has to either use this or
/// argue its own allow comment.
pub(crate) fn step_u32(step: usize) -> u32 {
    debug_assert!(step <= u32::MAX as usize, "step counter overflowed u32: {step}");
    // lint: allow(r6): sole audited narrowing, guarded by the debug_assert above
    step as u32
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::Rng;

    /// `Collective` backed by one rank's mesh endpoint (any transport) —
    /// the unit-test adapter for the row-split optimizer paths (the
    /// engine's production adapters live in shard/engine.rs).
    pub struct MeshColl<T: crate::shard::Transport = crate::shard::InProc>(
        pub crate::shard::Comm<T>,
    );

    impl<T: crate::shard::Transport> Collective for MeshColl<T> {
        fn all_reduce_sum(&mut self, buf: &mut [f32]) {
            self.0.all_reduce_sum(buf, 256).expect("test mesh peer lost");
        }
    }

    /// Random parameter/gradient fixture.
    pub fn fixture(shapes: &[Vec<usize>], seed: u64) -> (Vec<Tensor>, Vec<Tensor>) {
        let mut rng = Rng::new(seed);
        let params = shapes
            .iter()
            .map(|s| Tensor::from_fn(s, |_| rng.normal()))
            .collect();
        let grads = shapes
            .iter()
            .map(|s| Tensor::from_fn(s, |_| rng.normal() * 0.1))
            .collect();
        (params, grads)
    }

    /// Every optimizer must move parameters and keep them finite.
    pub fn check_step_sanity(name: &str) {
        let shapes = vec![vec![13, 7], vec![5], vec![3, 4, 2]];
        let (mut params, grads) = fixture(&shapes, 42);
        let before = params.clone();
        let mut opt = by_name(name, &shapes).expect("known optimizer");
        for _ in 0..5 {
            opt.step(&mut params, &grads, 1e-2);
        }
        let mut moved = 0;
        for (p, b) in params.iter().zip(&before) {
            check_finite(&format!("{name}: parameters"), p.data()).expect("finite parameters");
            for (&x, &y) in p.data().iter().zip(b.data()) {
                if (x - y).abs() > 1e-8 {
                    moved += 1;
                }
            }
        }
        assert!(moved > 0, "{name}: parameters did not move");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_optimizers_step_sanely() {
        for name in ALL {
            testutil::check_step_sanity(name);
        }
    }

    #[test]
    fn unknown_name_errors_with_the_known_list() {
        let err = by_name("adamw", &[vec![4, 4]]).unwrap_err().to_string();
        assert!(err.contains("unknown optimizer"), "{err}");
        assert!(err.contains("alada"), "should list known names: {err}");
    }

    /// The canonical state layout contract behind elastic checkpointing:
    /// every optimizer's `export_state` is exactly `tensor_state_elems`
    /// per tensor, and importing the blob into a fresh instance resumes
    /// the trajectory bit-for-bit.
    #[test]
    fn state_export_import_round_trips_every_optimizer() {
        let shapes = vec![vec![9, 4], vec![6], vec![3, 2, 5], vec![]];
        for name in ALL {
            let mut opt = by_name(name, &shapes).unwrap();
            let (mut params, grads) = testutil::fixture(&shapes, 7);
            for _ in 0..3 {
                opt.step(&mut params, &grads, 1e-2);
            }
            let want: usize = shapes.iter().map(|s| tensor_state_elems(name, s)).sum();
            let mut blob = Vec::new();
            opt.export_state(&mut blob);
            assert_eq!(blob.len(), want, "{name}: canonical layout length");
            let mut fresh = by_name(name, &shapes).unwrap();
            fresh.import_state(&shapes, &blob, 3).unwrap();
            let (mut pa, mut pb) = (params.clone(), params.clone());
            for _ in 0..2 {
                opt.step(&mut pa, &grads, 1e-2);
                fresh.step(&mut pb, &grads, 1e-2);
            }
            assert_eq!(pa, pb, "{name}: resumed trajectory diverged");
            // wrong-length blobs are a clean error, never a panic
            assert!(fresh.import_state(&shapes, &blob[..blob.len() / 2], 3).is_err() || want == 0);
        }
    }

    #[test]
    fn overhead_ordering_matches_paper() {
        // Table IV's story: Adam overhead 2mn ≫ Adafactor/Alada O(m+n).
        let shapes = vec![vec![512, 384]];
        let adam = by_name("adam", &shapes).unwrap();
        let adafactor = by_name("adafactor", &shapes).unwrap();
        let alada = by_name("alada", &shapes).unwrap();
        assert_eq!(adam.state_overhead_bytes(), 2 * 512 * 384 * 4);
        assert!(adafactor.state_overhead_bytes() < adam.state_overhead_bytes() / 100);
        assert!(alada.state_overhead_bytes() < adam.state_overhead_bytes() / 100);
        assert!(alada.aliases_grad_slot());
    }
}
