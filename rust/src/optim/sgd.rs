//! Stochastic gradient descent with optional heavy-ball momentum.
//!
//! The zero-overhead baseline the paper measures all "memory overheads"
//! against (footnote 1): plain SGD keeps no optimizer state at all;
//! SGD-momentum keeps one mn buffer.

use anyhow::{ensure, Result};

use super::Optimizer;
use crate::tensor::Tensor;

pub struct Sgd {
    momentum: f32,
    velocity: Option<Vec<Tensor>>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Sgd {
        Sgd { momentum, velocity: None }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        if self.momentum == 0.0 {
            for (p, g) in params.iter_mut().zip(grads) {
                p.axpy_inplace(g, -lr);
            }
            return;
        }
        let velocity = self
            .velocity
            .get_or_insert_with(|| params.iter().map(|p| Tensor::zeros(p.shape())).collect());
        for ((p, g), v) in params.iter_mut().zip(grads).zip(velocity.iter_mut()) {
            v.ema_inplace(g, self.momentum, 1.0);
            p.axpy_inplace(v, -lr);
        }
    }

    fn state_overhead_bytes(&self) -> usize {
        self.velocity
            .as_ref()
            .map(|v| v.iter().map(|t| t.len() * 4).sum())
            .unwrap_or(0)
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        // velocity still unallocated (no step yet) exports as nothing;
        // callers pad to the canonical length with zeros — the value a
        // first step would start from anyway.
        if let Some(v) = &self.velocity {
            for t in v {
                out.extend_from_slice(t.data());
            }
        }
    }

    fn import_state(&mut self, shapes: &[Vec<usize>], data: &[f32], _step: usize) -> Result<()> {
        if self.momentum == 0.0 {
            ensure!(data.is_empty(), "sgd keeps no state, got {} elements", data.len());
            return Ok(());
        }
        let total: usize = shapes.iter().map(|s| s.iter().product::<usize>().max(1)).sum();
        ensure!(
            data.len() == total,
            "sgdm state has {} elements, shapes imply {total}",
            data.len()
        );
        let mut velocity = Vec::with_capacity(shapes.len());
        let mut off = 0;
        for s in shapes {
            let n = s.iter().product::<usize>().max(1);
            velocity.push(Tensor::new(data[off..off + n].to_vec(), s));
            off += n;
        }
        self.velocity = Some(velocity);
        Ok(())
    }

    fn name(&self) -> &'static str {
        if self.momentum == 0.0 {
            "sgd"
        } else {
            "sgdm"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_is_stateless() {
        let mut opt = Sgd::new(0.0);
        let mut params = vec![Tensor::full(&[4], 1.0)];
        let grads = vec![Tensor::full(&[4], 0.5)];
        opt.step(&mut params, &grads, 0.1);
        assert!((params[0].data()[0] - 0.95).abs() < 1e-6);
        assert_eq!(opt.state_overhead_bytes(), 0);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(0.9);
        let mut params = vec![Tensor::zeros(&[1])];
        let grads = vec![Tensor::full(&[1], 1.0)];
        opt.step(&mut params, &grads, 1.0);
        let after1 = params[0].data()[0]; // -1
        opt.step(&mut params, &grads, 1.0);
        let delta2 = params[0].data()[0] - after1; // -(0.9+1)
        assert!((after1 + 1.0).abs() < 1e-6);
        assert!((delta2 + 1.9).abs() < 1e-6);
        assert_eq!(opt.state_overhead_bytes(), 4);
    }
}
