//! Adafactor (Shazeer & Stern 2018), configured as in the paper §VI-A:
//! first moment disabled, factored second moment with β₂ = 0.999,
//! external step-size schedule (no relative-update clipping).
//!
//! Matrix parameters keep row/column mean accumulators (O(m + n));
//! vectors and scalars fall back to a full accumulator — exactly the
//! published recipe.

use anyhow::{ensure, Result};

use super::reshape::balanced_split;
use super::Optimizer;
use crate::tensor::{kernels, Tensor};

enum Slot {
    Factored { r: Vec<f32>, c: Vec<f32>, rows: usize, cols: usize },
    Full(Tensor),
}

pub struct Adafactor {
    beta2: f32,
    eps: f32,
    t: u32,
    slots: Vec<Slot>,
}

impl Adafactor {
    pub fn new(beta2: f32, eps: f32, shapes: &[Vec<usize>]) -> Adafactor {
        let slots = shapes
            .iter()
            .map(|s| {
                let (rows, cols) = balanced_split(s);
                if rows >= 2 && cols >= 2 {
                    Slot::Factored { r: vec![0.0; rows], c: vec![0.0; cols], rows, cols }
                } else {
                    Slot::Full(Tensor::zeros(s))
                }
            })
            .collect();
        Adafactor { beta2, eps, t: 0, slots }
    }
}

impl Optimizer for Adafactor {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let (b2, eps) = (self.beta2, self.eps);
        let bc = 1.0 / (1.0 - b2.powi(self.t as i32 + 1));
        for (slot, (x, g)) in self.slots.iter_mut().zip(params.iter_mut().zip(grads)) {
            match slot {
                Slot::Factored { r, c, rows, cols } => {
                    let (rows, cols) = (*rows, *cols);
                    let gd = g.data();
                    // accumulate row/col means of V = g² + ε in one pass
                    // (vectorized row kernel shared with CAME)
                    let mut rsum = vec![0.0f32; rows];
                    let mut csum = vec![0.0f32; cols];
                    for i in 0..rows {
                        rsum[i] = kernels::sq_eps_rowcol(&gd[i * cols..(i + 1) * cols], &mut csum, eps);
                    }
                    kernels::factor_ema(r, &rsum, b2, cols as f32);
                    kernels::factor_ema(c, &csum, b2, rows as f32);
                    // rec(r, c) = r̂ ĉᵀ / mean(r̂); descent in a second pass
                    let mean_r = kernels::sum(r) / rows as f32 * bc;
                    let inv_mean = 1.0 / mean_r;
                    let xd = x.data_mut();
                    for i in 0..rows {
                        let ri = r[i] * bc;
                        let grow = &gd[i * cols..(i + 1) * cols];
                        let xrow = &mut xd[i * cols..(i + 1) * cols];
                        kernels::factored_descent_row(xrow, grow, c, ri, bc, inv_mean, lr, eps);
                    }
                }
                Slot::Full(u) => {
                    u.zip_inplace(g, |ui, gi| b2 * ui + (1.0 - b2) * (gi * gi + eps));
                    let ud = u.data();
                    for (i, xi) in x.data_mut().iter_mut().enumerate() {
                        *xi -= lr * g.data()[i] / ((ud[i] * bc).sqrt() + eps);
                    }
                }
            }
        }
        self.t += 1;
    }

    fn state_overhead_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| match s {
                Slot::Factored { r, c, .. } => (r.len() + c.len()) * 4,
                Slot::Full(t) => t.len() * 4,
            })
            .sum()
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        for s in &self.slots {
            match s {
                Slot::Factored { r, c, .. } => {
                    out.extend_from_slice(r);
                    out.extend_from_slice(c);
                }
                Slot::Full(t) => out.extend_from_slice(t.data()),
            }
        }
    }

    fn import_state(&mut self, _shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()> {
        let total: usize = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Factored { r, c, .. } => r.len() + c.len(),
                Slot::Full(t) => t.len(),
            })
            .sum();
        ensure!(
            data.len() == total,
            "adafactor state has {} elements, optimizer holds {total}",
            data.len()
        );
        ensure!(step <= u32::MAX as usize, "step counter {step} out of range");
        let mut off = 0;
        for s in &mut self.slots {
            match s {
                Slot::Factored { r, c, .. } => {
                    r.copy_from_slice(&data[off..off + r.len()]);
                    off += r.len();
                    c.copy_from_slice(&data[off..off + c.len()]);
                    off += c.len();
                }
                Slot::Full(t) => {
                    let n = t.len();
                    t.data_mut().copy_from_slice(&data[off..off + n]);
                    off += n;
                }
            }
        }
        self.t = super::step_u32(step);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "adafactor"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matrix_params_are_factored() {
        let shapes = vec![vec![32, 16], vec![10]];
        let opt = Adafactor::new(0.999, 1e-8, &shapes);
        // 32+16 factored + 10 full
        assert_eq!(opt.state_overhead_bytes(), (32 + 16 + 10) * 4);
    }

    #[test]
    fn reconstruction_tracks_uniform_variance() {
        // With a constant gradient the factored estimate should approach
        // the true uniform second moment, making steps ≈ lr-sized.
        let shapes = vec![vec![8, 8]];
        let mut opt = Adafactor::new(0.9, 1e-30, &shapes);
        let mut params = vec![Tensor::zeros(&[8, 8])];
        let grads = vec![Tensor::full(&[8, 8], 2.0)];
        for _ in 0..200 {
            opt.step(&mut params, &grads, 0.0);
        }
        let before = params[0].data()[0];
        opt.step(&mut params, &grads, 0.01);
        let step = before - params[0].data()[0];
        assert!((step - 0.01).abs() < 1e-3, "step {step}");
    }

    #[test]
    fn random_steps_stay_finite() {
        let shapes = vec![vec![6, 9]];
        let mut opt = Adafactor::new(0.999, 1e-8, &shapes);
        let mut rng = Rng::new(1);
        let mut params = vec![Tensor::from_fn(&[6, 9], |_| rng.normal())];
        for _ in 0..50 {
            let g = vec![Tensor::from_fn(&[6, 9], |_| rng.normal())];
            opt.step(&mut params, &g, 1e-2);
        }
        assert!(params[0].data().iter().all(|x| x.is_finite()));
    }
}
