//! SM3 (Anil et al. 2019) — memory-efficient adaptive method from the
//! paper's related work. Keeps per-row and per-column *max* accumulators;
//! the per-entry second-moment estimate is min(r_i, c_j).

use anyhow::{ensure, Result};

use super::reshape::balanced_split;
use super::Optimizer;
use crate::tensor::Tensor;

struct Slot {
    r: Vec<f32>,
    c: Vec<f32>,
    rows: usize,
    cols: usize,
}

pub struct Sm3 {
    eps: f32,
    slots: Vec<Slot>,
}

impl Sm3 {
    pub fn new(eps: f32, shapes: &[Vec<usize>]) -> Sm3 {
        let slots = shapes
            .iter()
            .map(|s| {
                let (rows, cols) = balanced_split(s);
                Slot { r: vec![0.0; rows], c: vec![0.0; cols], rows, cols }
            })
            .collect();
        Sm3 { eps, slots }
    }
}

impl Optimizer for Sm3 {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let eps = self.eps;
        for (slot, (x, g)) in self.slots.iter_mut().zip(params.iter_mut().zip(grads)) {
            let (rows, cols) = (slot.rows, slot.cols);
            let gd = g.data();
            let xd = x.data_mut();
            // SM3-I: nu_ij = min(r_i, c_j) + g², then fold maxima back.
            let mut new_r = vec![0.0f32; rows];
            let mut new_c = vec![0.0f32; cols];
            for i in 0..rows {
                let grow = &gd[i * cols..(i + 1) * cols];
                let xrow = &mut xd[i * cols..(i + 1) * cols];
                let ri = slot.r[i];
                for j in 0..cols {
                    let nu = ri.min(slot.c[j]) + grow[j] * grow[j];
                    xrow[j] -= lr * grow[j] / (nu.sqrt() + eps);
                    new_r[i] = new_r[i].max(nu);
                    new_c[j] = new_c[j].max(nu);
                }
            }
            slot.r = new_r;
            slot.c = new_c;
        }
    }

    fn state_overhead_bytes(&self) -> usize {
        self.slots.iter().map(|s| (s.r.len() + s.c.len()) * 4).sum()
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        for s in &self.slots {
            out.extend_from_slice(&s.r);
            out.extend_from_slice(&s.c);
        }
    }

    fn import_state(&mut self, _shapes: &[Vec<usize>], data: &[f32], _step: usize) -> Result<()> {
        let total: usize = self.slots.iter().map(|s| s.r.len() + s.c.len()).sum();
        ensure!(
            data.len() == total,
            "sm3 state has {} elements, optimizer holds {total}",
            data.len()
        );
        let mut off = 0;
        for s in &mut self.slots {
            s.r.copy_from_slice(&data[off..off + s.r.len()]);
            off += s.r.len();
            s.c.copy_from_slice(&data[off..off + s.c.len()]);
            off += s.c.len();
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "sm3"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn accumulators_grow_monotonically() {
        let shapes = vec![vec![4, 4]];
        let mut opt = Sm3::new(1e-8, &shapes);
        let mut rng = Rng::new(2);
        let mut params = vec![Tensor::zeros(&[4, 4])];
        let mut prev_r = vec![0.0f32; 4];
        for _ in 0..10 {
            let g = vec![Tensor::from_fn(&[4, 4], |_| rng.normal())];
            opt.step(&mut params, &g, 1e-2);
            for (new, old) in opt.slots[0].r.iter().zip(&prev_r) {
                assert!(new >= old, "SM3 row accumulator must be monotone");
            }
            prev_r = opt.slots[0].r.clone();
        }
    }

    #[test]
    fn overhead_is_sublinear() {
        let shapes = vec![vec![100, 100]];
        let opt = Sm3::new(1e-8, &shapes);
        assert_eq!(opt.state_overhead_bytes(), 200 * 4);
    }
}
