//! CAME (Luo et al. 2023) — confidence-guided memory-efficient method
//! from the paper's related work. Adafactor-style factored second moment
//! plus a factored *instability* matrix of (g − m)² that scales the
//! update confidence. Keeps a full first moment (mn), so its overhead
//! sits between Adam and Alada — exactly the gap Alada closes.

use anyhow::{ensure, Result};

use super::reshape::balanced_split;
use super::Optimizer;
use crate::tensor::{kernels, Tensor};

struct Slot {
    m: Tensor,
    r: Vec<f32>,
    c: Vec<f32>,
    ur: Vec<f32>,
    uc: Vec<f32>,
    rows: usize,
    cols: usize,
}

pub struct Came {
    beta1: f32,
    beta2: f32,
    beta3: f32,
    eps: f32,
    t: u32,
    slots: Vec<Slot>,
}

impl Came {
    pub fn new(beta1: f32, beta2: f32, beta3: f32, eps: f32, shapes: &[Vec<usize>]) -> Came {
        let slots = shapes
            .iter()
            .map(|s| {
                let (rows, cols) = balanced_split(s);
                Slot {
                    m: Tensor::zeros(s),
                    r: vec![0.0; rows],
                    c: vec![0.0; cols],
                    ur: vec![0.0; rows],
                    uc: vec![0.0; cols],
                    rows,
                    cols,
                }
            })
            .collect();
        Came { beta1, beta2, beta3, eps, t: 0, slots }
    }
}

impl Optimizer for Came {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let (b1, b2, b3, eps) = (self.beta1, self.beta2, self.beta3, self.eps);
        let bc2 = 1.0 / (1.0 - b2.powi(self.t as i32 + 1));
        for (slot, (x, g)) in self.slots.iter_mut().zip(params.iter_mut().zip(grads)) {
            let (rows, cols) = (slot.rows, slot.cols);
            let gd = g.data();

            // factored second moment of g² (Adafactor part; vectorized
            // row kernels shared through tensor::kernels)
            let mut rsum = vec![0.0f32; rows];
            let mut csum = vec![0.0f32; cols];
            for i in 0..rows {
                rsum[i] = kernels::sq_eps_rowcol(&gd[i * cols..(i + 1) * cols], &mut csum, eps);
            }
            kernels::factor_ema(&mut slot.r, &rsum, b2, cols as f32);
            kernels::factor_ema(&mut slot.c, &csum, b2, rows as f32);
            let mean_r = kernels::sum(&slot.r) / rows as f32 * bc2;
            let inv_mean = 1.0 / mean_r.max(1e-30);

            // first moment (full) + instability statistics of (u_hat − m)²
            slot.m.ema_inplace(g, b1, 1.0 - b1);
            let md = slot.m.data();
            let mut inst_r = vec![0.0f32; rows];
            let mut inst_c = vec![0.0f32; cols];
            // u_hat = g / sqrt(rec(r, c)); instability = (m − u_hat)²
            for i in 0..rows {
                let ri = slot.r[i] * bc2;
                let grow = &gd[i * cols..(i + 1) * cols];
                let mrow = &md[i * cols..(i + 1) * cols];
                inst_r[i] =
                    kernels::came_instability_row(mrow, grow, &slot.c, ri, bc2, inv_mean, eps, &mut inst_c);
            }
            kernels::factor_ema(&mut slot.ur, &inst_r, b3, cols as f32);
            kernels::factor_ema(&mut slot.uc, &inst_c, b3, rows as f32);
            let mean_ur = kernels::sum(&slot.ur) / rows as f32;
            let inv_mean_u = 1.0 / mean_ur.max(1e-30);

            // confidence-scaled descent: x -= lr * m / sqrt(rec(ur, uc))
            let xd = x.data_mut();
            for i in 0..rows {
                let uri = slot.ur[i];
                let mrow = &md[i * cols..(i + 1) * cols];
                let xrow = &mut xd[i * cols..(i + 1) * cols];
                kernels::came_descent_row(xrow, mrow, &slot.uc, uri, inv_mean_u, lr, eps);
            }
        }
        self.t += 1;
    }

    fn state_overhead_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| (s.m.len() + s.r.len() + s.c.len() + s.ur.len() + s.uc.len()) * 4)
            .sum()
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        for s in &self.slots {
            out.extend_from_slice(s.m.data());
            out.extend_from_slice(&s.r);
            out.extend_from_slice(&s.c);
            out.extend_from_slice(&s.ur);
            out.extend_from_slice(&s.uc);
        }
    }

    fn import_state(&mut self, _shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()> {
        let total: usize = self
            .slots
            .iter()
            .map(|s| s.m.len() + s.r.len() + s.c.len() + s.ur.len() + s.uc.len())
            .sum();
        ensure!(
            data.len() == total,
            "came state has {} elements, optimizer holds {total}",
            data.len()
        );
        ensure!(step <= u32::MAX as usize, "step counter {step} out of range");
        let mut off = 0;
        for s in &mut self.slots {
            let n = s.m.len();
            s.m.data_mut().copy_from_slice(&data[off..off + n]);
            off += n;
            for part in [&mut s.r, &mut s.c, &mut s.ur, &mut s.uc] {
                part.copy_from_slice(&data[off..off + part.len()]);
                off += part.len();
            }
        }
        self.t = super::step_u32(step);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "came"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn overhead_between_alada_and_adam() {
        let shapes = vec![vec![64, 48]];
        let came = Came::new(0.9, 0.999, 0.9995, 1e-8, &shapes);
        let mn = 64 * 48 * 4;
        let over = came.state_overhead_bytes();
        assert!(over > (64 + 48 + 1) * 4, "more than Alada");
        assert!(over < 2 * mn, "less than Adam");
    }

    #[test]
    fn steps_stay_finite() {
        let shapes = vec![vec![8, 6]];
        let mut opt = Came::new(0.9, 0.999, 0.9995, 1e-8, &shapes);
        let mut rng = Rng::new(4);
        let mut params = vec![Tensor::from_fn(&[8, 6], |_| rng.normal())];
        for _ in 0..40 {
            let g = vec![Tensor::from_fn(&[8, 6], |_| rng.normal())];
            opt.step(&mut params, &g, 1e-2);
        }
        assert!(params[0].data().iter().all(|x| x.is_finite()));
    }
}
