//! Adam (Kingma & Ba 2015) with bias correction — the paper's primary
//! comparator (Eq. 2-3). State: two mn buffers (M and U), the 2mn
//! overhead Table IV measures. The update is the fused single-pass
//! `tensor::kernels::adam_update` (one sweep of memory traffic instead
//! of three).

use anyhow::{ensure, Result};

use super::Optimizer;
use crate::tensor::{kernels, Tensor};

pub struct Adam {
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: u32,
    m: Vec<Tensor>,
    u: Vec<Tensor>,
}

impl Adam {
    pub fn new(beta1: f32, beta2: f32, eps: f32, shapes: &[Vec<usize>]) -> Adam {
        Adam {
            beta1,
            beta2,
            eps,
            t: 0,
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            u: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let bc1 = 1.0 / (1.0 - b1.powi(self.t as i32 + 1));
        let bc2 = 1.0 / (1.0 - b2.powi(self.t as i32 + 1));
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            kernels::adam_update(
                p.data_mut(),
                self.m[i].data_mut(),
                self.u[i].data_mut(),
                g.data(),
                b1,
                b2,
                bc1,
                bc2,
                lr,
                eps,
            );
        }
        self.t += 1;
    }

    fn state_overhead_bytes(&self) -> usize {
        self.m.iter().chain(&self.u).map(|t| t.len() * 4).sum()
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        // canonical field order: per tensor, [m_t, u_t] interleaved
        for (m, u) in self.m.iter().zip(&self.u) {
            out.extend_from_slice(m.data());
            out.extend_from_slice(u.data());
        }
    }

    fn import_state(&mut self, _shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()> {
        let total: usize = self.m.iter().chain(&self.u).map(|t| t.len()).sum();
        ensure!(
            data.len() == total,
            "adam state has {} elements, optimizer holds {total}",
            data.len()
        );
        ensure!(step <= u32::MAX as usize, "step counter {step} out of range");
        let mut off = 0;
        for (m, u) in self.m.iter_mut().zip(&mut self.u) {
            let n = m.len();
            m.data_mut().copy_from_slice(&data[off..off + n]);
            u.data_mut().copy_from_slice(&data[off + n..off + 2 * n]);
            off += 2 * n;
        }
        self.t = super::step_u32(step);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// First step of Adam moves by ≈ lr regardless of gradient scale
    /// (the scale-invariance that motivates adaptivity).
    #[test]
    fn first_step_is_lr_sized() {
        for scale in [1e-3f32, 1.0, 1e3] {
            let shapes = vec![vec![1]];
            let mut opt = Adam::new(0.9, 0.999, 1e-8, &shapes);
            let mut params = vec![Tensor::zeros(&[1])];
            let grads = vec![Tensor::full(&[1], scale)];
            opt.step(&mut params, &grads, 0.01);
            assert!(
                (params[0].data()[0] + 0.01).abs() < 1e-4,
                "scale {scale}: step {}",
                params[0].data()[0]
            );
        }
    }

    #[test]
    fn overhead_is_2mn() {
        let shapes = vec![vec![10, 20], vec![5]];
        let opt = Adam::new(0.9, 0.999, 1e-8, &shapes);
        assert_eq!(opt.state_overhead_bytes(), 2 * (200 + 5) * 4);
    }
}
