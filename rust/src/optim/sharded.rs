//! `ShardedOptimizer` — ZeRO-style partitioned adapter over any optimizer.
//!
//! One logical optimizer, N physical shards: rank r constructs the
//! wrapped optimizer over only the tensor shapes it owns (a contiguous,
//! tensor-aligned slice of the flat parameter space from
//! `shard::Partition`) and applies updates to exactly those tensors.
//! Because every optimizer's state in this crate is per-tensor, the
//! partitioned update is *bit-identical* to what the unsharded optimizer
//! would do to the owned tensors given the same gradients — over one
//! rank the adapter is exactly the wrapped optimizer, and across ranks
//! the per-rank `state_overhead_bytes` (64-byte aligned, the alignment a
//! real flat state buffer would need) sum to the unsharded total plus
//! padding. Both properties are pinned in rust/tests/proptests.rs.

use anyhow::Result;
use std::ops::Range;

use super::{by_name, Optimizer};
use crate::shard::Partition;
use crate::tensor::Tensor;

/// Per-rank state slices are padded to this alignment (cache line /
/// bucket boundary), the accounting a packed flat state buffer needs.
pub const STATE_ALIGN: usize = 64;

pub struct ShardedOptimizer {
    inner: Box<dyn Optimizer + Send>,
    /// Tensor indices (into the *full* parameter list) this rank owns.
    owned: Range<usize>,
    /// Flat element offsets this rank owns — the slice of the engine's
    /// exchange buffer a reduce-scatter delivers here.
    owned_elems: Range<usize>,
    rank: usize,
    ranks: usize,
}

impl ShardedOptimizer {
    /// Build rank `rank`'s shard of optimizer `name` under `part`.
    pub fn new(name: &str, part: &Partition, rank: usize) -> Result<ShardedOptimizer> {
        let owned_shapes = part.owned_shapes(rank);
        Ok(ShardedOptimizer {
            inner: by_name(name, &owned_shapes)?,
            owned: part.tensor_range(rank),
            owned_elems: part.elem_range(rank),
            rank,
            ranks: part.ranks(),
        })
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Tensor indices this shard updates.
    pub fn owned(&self) -> Range<usize> {
        self.owned.clone()
    }

    /// Flat element offsets this shard updates (contiguous; the segment
    /// the shard engine's reduce-scatter targets at this rank).
    pub fn owned_elem_range(&self) -> Range<usize> {
        self.owned_elems.clone()
    }

    /// State bytes without the alignment padding (exact-sum bookkeeping).
    pub fn unpadded_state_bytes(&self) -> usize {
        self.inner.state_overhead_bytes()
    }
}

impl Optimizer for ShardedOptimizer {
    /// `params`/`grads` are the FULL lists; only the owned contiguous
    /// sub-range is read and updated.
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        let r = self.owned.clone();
        self.inner.step(&mut params[r.clone()], &grads[r], lr);
    }

    fn state_overhead_bytes(&self) -> usize {
        let b = self.inner.state_overhead_bytes();
        (b + STATE_ALIGN - 1) / STATE_ALIGN * STATE_ALIGN
    }

    fn aliases_grad_slot(&self) -> bool {
        self.inner.aliases_grad_slot()
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::fixture;

    #[test]
    fn one_rank_is_the_wrapped_optimizer_bit_for_bit() {
        let shapes = vec![vec![9, 4], vec![6], vec![3, 2, 5]];
        let part = Partition::plan(&shapes, 1);
        let mut sharded = ShardedOptimizer::new("alada", &part, 0).unwrap();
        let mut plain = by_name("alada", &shapes).unwrap();
        let (mut pa, grads) = fixture(&shapes, 11);
        let mut pb = pa.clone();
        for _ in 0..6 {
            sharded.step(&mut pa, &grads, 3e-3);
            plain.step(&mut pb, &grads, 3e-3);
        }
        assert_eq!(pa, pb);
    }

    #[test]
    fn shards_update_disjoint_tensors_identically_to_unsharded() {
        // Stepping every shard == stepping the unsharded optimizer,
        // bit-for-bit, because the partition is tensor-aligned.
        let shapes = vec![vec![8, 8], vec![12], vec![6, 4], vec![10], vec![4, 4, 4]];
        let ranks = 3;
        let part = Partition::plan(&shapes, ranks);
        let mut plain = by_name("alada", &shapes).unwrap();
        let (mut pa, grads) = fixture(&shapes, 21);
        let mut pb = pa.clone();
        let mut shards: Vec<ShardedOptimizer> =
            (0..ranks).map(|r| ShardedOptimizer::new("alada", &part, r).unwrap()).collect();
        for _ in 0..5 {
            plain.step(&mut pa, &grads, 1e-2);
            for s in shards.iter_mut() {
                s.step(&mut pb, &grads, 1e-2);
            }
        }
        assert_eq!(pa, pb);
    }

    #[test]
    fn padded_bytes_are_aligned_and_bounded() {
        let shapes = vec![vec![33, 7], vec![5], vec![2, 9]];
        for ranks in [1usize, 2, 3, 5] {
            let part = Partition::plan(&shapes, ranks);
            let total = by_name("alada", &shapes).unwrap().state_overhead_bytes();
            let mut sum_padded = 0;
            let mut sum_exact = 0;
            for r in 0..ranks {
                let s = ShardedOptimizer::new("alada", &part, r).unwrap();
                assert_eq!(s.state_overhead_bytes() % STATE_ALIGN, 0);
                assert!(s.state_overhead_bytes() >= s.unpadded_state_bytes());
                assert!(s.state_overhead_bytes() - s.unpadded_state_bytes() < STATE_ALIGN);
                sum_padded += s.state_overhead_bytes();
                sum_exact += s.unpadded_state_bytes();
            }
            assert_eq!(sum_exact, total, "ranks={ranks}");
            assert!(sum_padded >= total && sum_padded - total < ranks * STATE_ALIGN);
        }
    }

    #[test]
    fn unknown_name_is_a_result_error() {
        let part = Partition::plan(&[vec![4, 4]], 2);
        assert!(ShardedOptimizer::new("definitely-not-an-optimizer", &part, 0).is_err());
    }
}
