//! `ShardedOptimizer` — ZeRO-style partitioned adapter over any optimizer.
//!
//! One logical optimizer, N physical shards. The shard's shape follows
//! the optimizer's `partition_granularity`:
//!
//! * **Row-split Alada** — the shard is a partial-view `Alada` over the
//!   owned row ranges (sliced p and M window, replicated q and v₀); the
//!   cross-rank q/v₀ chunk reductions go through the `Collective` handed
//!   to `step_collective`. Bit-identical to the unsharded optimizer for
//!   any chunk-aligned cut (see optim/alada.rs module docs).
//! * **Row-split elementwise** (SGD/SGD-m/AdaGrad/Adam) — per-element
//!   state is exact under any cut; owned pieces are staged through
//!   scratch tensors around the wrapped optimizer's step.
//! * **Tensor-aligned** (Adafactor/CAME/SM3) — the PR-1 behaviour: the
//!   wrapped optimizer is built over the whole owned tensors, which is
//!   the only partition their coupled column statistics admit.
//!
//! Over one rank every variant is exactly the wrapped optimizer, and the
//! per-rank `state_overhead_bytes` (64-byte aligned, the alignment a
//! real flat state buffer would need) sum to the unsharded total plus
//! padding plus — for row-split Alada only — one replicated (q, v₀) per
//! extra owner of a split tensor. Pinned in rust/tests/proptests.rs.

use anyhow::{ensure, Result};
use std::ops::Range;

use super::alada::{Alada, AladaView};
use super::{
    by_name, partition_granularity, state_fields, tensor_state_elems, Collective,
    LocalCollective, Optimizer, PartitionGranularity, ALADA_DEFAULTS,
};
use crate::shard::partition::{Partition, Piece};
use crate::tensor::Tensor;

/// Per-rank state slices are padded to this alignment (cache line /
/// bucket boundary), the accounting a packed flat state buffer needs.
pub const STATE_ALIGN: usize = 64;

enum Inner {
    /// Whole-tensor ownership: the wrapped optimizer over the owned
    /// shapes, stepped on the contiguous owned sub-range of the lists.
    Tensors { opt: Box<dyn Optimizer + Send>, owned: Range<usize> },
    /// Row-split Alada partial view.
    AladaRows(Alada),
    /// Row-split elementwise optimizer over per-piece scratch tensors.
    Elems { opt: Box<dyn Optimizer + Send>, scratch_p: Vec<Tensor>, scratch_g: Vec<Tensor> },
}

pub struct ShardedOptimizer {
    inner: Inner,
    /// Owned sub-tensors, ascending (at most one per tensor).
    pieces: Vec<Piece>,
    /// Shapes the wrapped optimizer was built over (whole tensors for
    /// `Tensors`, flat piece lengths for `Elems`) — re-supplied to
    /// `import_state` for lazily-built state.
    piece_shapes: Vec<Vec<usize>>,
    /// Flat element offsets this rank owns — the slice of the engine's
    /// exchange buffer a reduce-scatter delivers here.
    owned_elems: Range<usize>,
    rank: usize,
    ranks: usize,
    /// True when some owned tensor's rows span more than one rank:
    /// stepping then REQUIRES a real collective (`step_collective`).
    needs_collective: bool,
}

impl ShardedOptimizer {
    /// Build rank `rank`'s shard of optimizer `name` under `part`.
    pub fn new(name: &str, part: &Partition, rank: usize) -> Result<ShardedOptimizer> {
        let pieces = part.pieces(rank);
        let owned_elems = part.elem_range(rank);
        let mut needs_collective = false;
        let mut piece_shapes: Vec<Vec<usize>> = Vec::new();
        let inner = match partition_granularity(name) {
            PartitionGranularity::Row if name == "alada" => {
                let owners = part.owner_counts();
                let mut views = Vec::new();
                let mut pi = 0usize;
                for (t, slot) in part.slots().iter().enumerate() {
                    let owned = pieces.get(pi).filter(|p| p.tensor == t);
                    if let Some(p) = owned {
                        pi += 1;
                        views.push(AladaView {
                            idx: t,
                            shape: slot.shape.clone(),
                            rows: p.rows.clone(),
                            shared: owners[t] > 1,
                        });
                    } else if owners[t] > 1 {
                        // shared tensor this rank owns nothing of: a
                        // pure-participation view (the collective is
                        // global, so every rank must join every shared
                        // tensor's reduction).
                        views.push(AladaView {
                            idx: t,
                            shape: slot.shape.clone(),
                            rows: 0..0,
                            shared: true,
                        });
                    }
                }
                let (b1, b2, eps) = ALADA_DEFAULTS;
                let alada = Alada::new_sharded(b1, b2, eps, &views);
                needs_collective = alada.needs_collective();
                Inner::AladaRows(alada)
            }
            PartitionGranularity::Row => {
                let shapes: Vec<Vec<usize>> = pieces.iter().map(|p| vec![p.elems()]).collect();
                let opt = by_name(name, &shapes)?;
                piece_shapes = shapes;
                // scratch buffers are built lazily at the first step, so
                // accounting-only construction stays cheap
                Inner::Elems { opt, scratch_p: Vec::new(), scratch_g: Vec::new() }
            }
            PartitionGranularity::Tensor => {
                let shapes: Vec<Vec<usize>> =
                    pieces.iter().map(|p| part.slots()[p.tensor].shape.clone()).collect();
                piece_shapes = shapes.clone();
                // validate the name first so unknown optimizers error as
                // such, not as a granularity mismatch
                let opt = by_name(name, &shapes)?;
                ensure!(
                    part.granularity() == PartitionGranularity::Tensor,
                    "optimizer {name:?} has per-tensor state and needs a tensor-aligned \
                     partition (plan with Partition::plan_for)"
                );
                let owned = match (pieces.first(), pieces.last()) {
                    (Some(a), Some(b)) => a.tensor..b.tensor + 1,
                    _ => part.n_tensors()..part.n_tensors(),
                };
                debug_assert_eq!(owned.len(), pieces.len());
                Inner::Tensors { opt, owned }
            }
        };
        Ok(ShardedOptimizer {
            inner,
            pieces,
            piece_shapes,
            owned_elems,
            rank,
            ranks: part.ranks(),
            needs_collective,
        })
    }

    /// Canonical length (f32 elements) of this shard's exported state —
    /// a pure function of (optimizer, partition, rank), so both sides of
    /// a checkpoint agree on slice sizes without reading payloads
    /// (`Partition::state_slice_elems` computes the same number from the
    /// partition alone; pinned equal in the tests below).
    pub fn state_elems(&self) -> usize {
        match &self.inner {
            Inner::AladaRows(_) => self
                .pieces
                .iter()
                .map(|p| p.elems() + p.rows.len() + p.cols + 1)
                .sum(),
            Inner::Elems { .. } => {
                let per_elem = state_fields(self.name()).len();
                per_elem * self.pieces.iter().map(|p| p.elems()).sum::<usize>()
            }
            Inner::Tensors { .. } => self
                .piece_shapes
                .iter()
                .map(|s| tensor_state_elems(self.name(), s))
                .sum(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn ranks(&self) -> usize {
        self.ranks
    }

    /// Owned sub-tensors (at most one per tensor, ascending).
    pub fn pieces(&self) -> &[Piece] {
        &self.pieces
    }

    /// Flat element offsets this shard updates (contiguous; the segment
    /// the shard engine's reduce-scatter targets at this rank).
    pub fn owned_elem_range(&self) -> Range<usize> {
        self.owned_elems.clone()
    }

    /// True when `step` must go through `step_collective` with a real
    /// cross-rank collective (some owned tensor is row-split).
    pub fn needs_collective(&self) -> bool {
        self.needs_collective
    }

    /// The wrapped optimizer, whichever inner form it takes.
    fn inner_opt(&self) -> &(dyn Optimizer + Send) {
        match &self.inner {
            Inner::Tensors { opt, .. } => opt.as_ref(),
            Inner::AladaRows(alada) => alada,
            Inner::Elems { opt, .. } => opt.as_ref(),
        }
    }

    /// State bytes without the alignment padding (exact-sum bookkeeping).
    pub fn unpadded_state_bytes(&self) -> usize {
        self.inner_opt().state_overhead_bytes()
    }

    /// One update. `params`/`grads` are the FULL lists; only the owned
    /// pieces are read and updated. `coll` carries the cross-rank
    /// reductions of row-split Alada (ignored by the other variants, so
    /// a no-op collective is fine for them).
    pub fn step_collective(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        lr: f32,
        coll: &mut dyn Collective,
    ) {
        match &mut self.inner {
            Inner::Tensors { opt, owned } => {
                let r = owned.clone();
                opt.step(&mut params[r.clone()], &grads[r], lr);
            }
            Inner::AladaRows(alada) => alada.step_with(params, grads, lr, coll),
            Inner::Elems { opt, scratch_p, scratch_g } => {
                if scratch_p.len() != self.pieces.len() {
                    *scratch_p =
                        self.pieces.iter().map(|p| Tensor::zeros(&[p.elems()])).collect();
                    *scratch_g = scratch_p.clone();
                }
                for (piece, (sp, sg)) in
                    self.pieces.iter().zip(scratch_p.iter_mut().zip(scratch_g.iter_mut()))
                {
                    let r = piece.local.clone();
                    sp.data_mut().copy_from_slice(&params[piece.tensor].data()[r.clone()]);
                    sg.data_mut().copy_from_slice(&grads[piece.tensor].data()[r]);
                }
                opt.step(&mut scratch_p[..], &scratch_g[..], lr);
                for (piece, sp) in self.pieces.iter().zip(scratch_p.iter()) {
                    params[piece.tensor].data_mut()[piece.local.clone()]
                        .copy_from_slice(sp.data());
                }
            }
        }
    }
}

impl Optimizer for ShardedOptimizer {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert!(
            !self.needs_collective,
            "this shard owns row-split tensors; step via step_collective with the engine's \
             collective"
        );
        self.step_collective(params, grads, lr, &mut LocalCollective);
    }

    fn state_overhead_bytes(&self) -> usize {
        let b = self.unpadded_state_bytes();
        (b + STATE_ALIGN - 1) / STATE_ALIGN * STATE_ALIGN
    }

    /// This shard's state in the canonical per-piece layout: for each
    /// owned piece (ascending), the optimizer's fields in
    /// `optim::state_fields` order (whole-tensor chunks for the
    /// tensor-aligned family). Always exactly `state_elems()` long —
    /// lazily-unallocated inner state (SGD-m before its first step) is
    /// padded with zeros, its semantic initial value.
    fn export_state(&self, out: &mut Vec<f32>) {
        let base = out.len();
        self.inner_opt().export_state(out);
        let want = base + self.state_elems();
        assert!(
            out.len() == want || out.len() == base,
            "inner {} exported {} state elements, canonical layout holds {}",
            self.name(),
            out.len() - base,
            want - base
        );
        out.resize(want, 0.0);
    }

    /// Restore a blob produced by `export_state` on a shard of the SAME
    /// partition and rank (cross-partition restores go through the
    /// reshard planner first — `shard::partition::plan_reshard`).
    fn import_state(&mut self, _shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()> {
        ensure!(
            data.len() == self.state_elems(),
            "state slice has {} elements, rank {}/{} of this partition holds {}",
            data.len(),
            self.rank,
            self.ranks,
            self.state_elems()
        );
        match &mut self.inner {
            Inner::AladaRows(alada) => alada.import_state(&[], data, step),
            Inner::Tensors { opt, .. } => opt.import_state(&self.piece_shapes, data, step),
            Inner::Elems { opt, .. } => opt.import_state(&self.piece_shapes, data, step),
        }
    }

    fn aliases_grad_slot(&self) -> bool {
        self.inner_opt().aliases_grad_slot()
    }

    fn name(&self) -> &'static str {
        self.inner_opt().name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::{fixture, MeshColl};
    use crate::shard::mesh;

    #[test]
    fn one_rank_is_the_wrapped_optimizer_bit_for_bit() {
        let shapes = vec![vec![9, 4], vec![6], vec![3, 2, 5]];
        for name in ["alada", "adam", "adafactor", "sgdm"] {
            let part = Partition::plan_for(name, &shapes, 1);
            let mut sharded = ShardedOptimizer::new(name, &part, 0).unwrap();
            let mut plain = by_name(name, &shapes).unwrap();
            let (mut pa, grads) = fixture(&shapes, 11);
            let mut pb = pa.clone();
            for _ in 0..6 {
                sharded.step(&mut pa, &grads, 3e-3);
                plain.step(&mut pb, &grads, 3e-3);
            }
            assert_eq!(pa, pb, "{name}");
        }
    }

    /// The tentpole contract: stepping every row-split shard over a real
    /// mesh == stepping the unsharded optimizer, bit-for-bit, at rank
    /// counts that cut the dominant matrix at different chunk boundaries.
    #[test]
    fn row_split_shards_match_unsharded_bit_for_bit() {
        // [40, 6] dominates and splits; the rest ride along.
        let shapes = vec![vec![40, 6], vec![12], vec![6, 4], vec![10]];
        let (mut pa, grads) = fixture(&shapes, 21);
        let mut plain = by_name("alada", &shapes).unwrap();
        for _ in 0..5 {
            plain.step(&mut pa, &grads, 1e-2);
        }
        for ranks in [1usize, 2, 3, 4, 7] {
            let part = Partition::plan_for("alada", &shapes, ranks);
            let outs: Vec<(Vec<Piece>, Vec<Tensor>)> = std::thread::scope(|s| {
                let handles: Vec<_> = mesh(ranks)
                    .expect("mesh")
                    .into_iter()
                    .enumerate()
                    .map(|(r, comm)| {
                        let part = &part;
                        let shapes = &shapes;
                        let grads = &grads;
                        s.spawn(move || {
                            let (mut pb, _) = fixture(shapes, 21);
                            let mut shard = ShardedOptimizer::new("alada", part, r).unwrap();
                            let mut coll = MeshColl(comm);
                            for _ in 0..5 {
                                shard.step_collective(&mut pb, grads, 1e-2, &mut coll);
                            }
                            (shard.pieces().to_vec(), pb)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("rank thread")).collect()
            });
            // stitch each rank's owned pieces into one parameter set
            let (mut stitched, _) = fixture(&shapes, 21);
            for (pieces, pb) in &outs {
                for piece in pieces {
                    stitched[piece.tensor].data_mut()[piece.local.clone()]
                        .copy_from_slice(&pb[piece.tensor].data()[piece.local.clone()]);
                }
            }
            for (t, (a, b)) in stitched.iter().zip(&pa).enumerate() {
                for (x, y) in a.data().iter().zip(b.data()) {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "ranks={ranks} tensor={t}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn row_split_elementwise_shards_match_unsharded() {
        let shapes = vec![vec![30, 4], vec![8], vec![5, 5]];
        for name in ["sgd", "sgdm", "adagrad", "adam"] {
            let part = Partition::plan_for(name, &shapes, 3);
            let mut plain = by_name(name, &shapes).unwrap();
            let (mut pa, grads) = fixture(&shapes, 33);
            let mut pb = pa.clone();
            let mut shards: Vec<ShardedOptimizer> =
                (0..3).map(|r| ShardedOptimizer::new(name, &part, r).unwrap()).collect();
            for _ in 0..5 {
                plain.step(&mut pa, &grads, 1e-2);
                for s in shards.iter_mut() {
                    // elementwise state needs no collective
                    s.step(&mut pb, &grads, 1e-2);
                }
            }
            assert_eq!(pa, pb, "{name}");
        }
    }

    #[test]
    fn tensor_aligned_shards_update_disjoint_tensors_identically() {
        let shapes = vec![vec![8, 8], vec![12], vec![6, 4], vec![10], vec![4, 4, 4]];
        let ranks = 3;
        for name in ["adafactor", "came", "sm3"] {
            let part = Partition::plan_for(name, &shapes, ranks);
            assert_eq!(part.granularity(), PartitionGranularity::Tensor);
            let mut plain = by_name(name, &shapes).unwrap();
            let (mut pa, grads) = fixture(&shapes, 21);
            let mut pb = pa.clone();
            let mut shards: Vec<ShardedOptimizer> =
                (0..ranks).map(|r| ShardedOptimizer::new(name, &part, r).unwrap()).collect();
            for _ in 0..5 {
                plain.step(&mut pa, &grads, 1e-2);
                for s in shards.iter_mut() {
                    s.step(&mut pb, &grads, 1e-2);
                }
            }
            assert_eq!(pa, pb, "{name}");
        }
    }

    #[test]
    fn padded_bytes_are_aligned_and_replication_accounted() {
        let shapes = vec![vec![33, 7], vec![5], vec![2, 9]];
        for ranks in [1usize, 2, 3, 5] {
            let part = Partition::plan_for("alada", &shapes, ranks);
            let total = by_name("alada", &shapes).unwrap().state_overhead_bytes();
            // exact expected replication: one (q, v₀) per extra owner
            let repl = part.alada_replication_bytes();
            let mut sum_padded = 0;
            let mut sum_exact = 0;
            for r in 0..ranks {
                let s = ShardedOptimizer::new("alada", &part, r).unwrap();
                assert_eq!(s.state_overhead_bytes() % STATE_ALIGN, 0);
                assert!(s.state_overhead_bytes() >= s.unpadded_state_bytes());
                assert!(s.state_overhead_bytes() - s.unpadded_state_bytes() < STATE_ALIGN);
                sum_padded += s.state_overhead_bytes();
                sum_exact += s.unpadded_state_bytes();
            }
            assert_eq!(sum_exact, total + repl, "ranks={ranks}");
            assert!(sum_padded >= sum_exact && sum_padded - sum_exact < ranks * STATE_ALIGN);
        }
    }

    /// Both sides of the checkpoint contract compute slice sizes
    /// independently — the optimizer from its pieces, the planner from
    /// the partition — and they must agree for every optimizer and cut.
    #[test]
    fn state_elems_agree_with_partition_layout() {
        let shapes = vec![vec![40, 6], vec![12], vec![6, 4], vec![10]];
        for name in ["alada", "adam", "sgdm", "sgd", "adagrad", "adafactor", "came", "sm3"] {
            for ranks in [1usize, 2, 3, 5, 9] {
                let part = Partition::plan_for(name, &shapes, ranks);
                for r in 0..ranks {
                    let s = ShardedOptimizer::new(name, &part, r).unwrap();
                    assert_eq!(
                        s.state_elems(),
                        part.state_slice_elems(name, r),
                        "{name} at {ranks} ranks, rank {r}"
                    );
                }
            }
        }
    }

    /// Lazily-built state that never stepped exports as its semantic
    /// initial value (zeros), at the canonical length.
    #[test]
    fn sgdm_pre_step_export_pads_to_canonical_zeros() {
        let shapes = vec![vec![6, 4], vec![5]];
        let part = Partition::plan_for("sgdm", &shapes, 2);
        let s = ShardedOptimizer::new("sgdm", &part, 0).unwrap();
        let mut v = Vec::new();
        s.export_state(&mut v);
        assert_eq!(v.len(), s.state_elems());
        assert!(v.iter().all(|&x| x == 0.0));
        assert!(s.state_elems() > 0);
    }

    /// Optimizer-level elastic round trip: step 2-way shards, export,
    /// reshard the slices to 3 ranks, import, and the 3-way shards
    /// continue the unsharded trajectory bit-for-bit. (The engine-level
    /// end-to-end version lives in rust/tests/elastic_resume.rs.)
    #[test]
    fn exported_state_reshards_across_rank_counts() {
        use crate::shard::partition::plan_reshard;
        let shapes = vec![vec![30, 4], vec![8], vec![5, 5]];
        for name in ["adam", "sgdm", "adagrad", "adafactor", "sm3"] {
            let (mut pa, grads) = fixture(&shapes, 33);
            let mut pb = pa.clone();
            let mut plain = by_name(name, &shapes).unwrap();
            let old_part = Partition::plan_for(name, &shapes, 2);
            let mut old: Vec<ShardedOptimizer> =
                (0..2).map(|r| ShardedOptimizer::new(name, &old_part, r).unwrap()).collect();
            for _ in 0..4 {
                plain.step(&mut pa, &grads, 1e-2);
                for s in old.iter_mut() {
                    s.step(&mut pb, &grads, 1e-2);
                }
            }
            assert_eq!(pa, pb, "{name}: pre-checkpoint shards diverged");
            let slices: Vec<Vec<f32>> = old
                .iter()
                .map(|s| {
                    let mut v = Vec::new();
                    s.export_state(&mut v);
                    v
                })
                .collect();
            let new_part = Partition::plan_for(name, &shapes, 3);
            let mut new: Vec<ShardedOptimizer> = (0..3)
                .map(|r| {
                    let mut s = ShardedOptimizer::new(name, &new_part, r).unwrap();
                    let plan = plan_reshard(name, &old_part, &new_part, r).unwrap();
                    let mut blob = vec![0.0f32; new_part.state_slice_elems(name, r)];
                    for c in &plan {
                        blob[c.dst.clone()].copy_from_slice(&slices[c.src_rank][c.src.clone()]);
                    }
                    s.import_state(&[], &blob, 4).unwrap();
                    s
                })
                .collect();
            for _ in 0..3 {
                plain.step(&mut pa, &grads, 1e-2);
                for s in new.iter_mut() {
                    s.step(&mut pb, &grads, 1e-2);
                }
            }
            assert_eq!(pa, pb, "{name}: resumed 3-way shards diverged");
        }
    }

    #[test]
    fn unknown_name_is_a_result_error() {
        let part = Partition::plan(&[vec![4, 4]], 2);
        let err = ShardedOptimizer::new("definitely-not-an-optimizer", &part, 0);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("unknown optimizer"));
    }

    #[test]
    fn tensor_granularity_optimizer_rejects_row_partition() {
        let part = Partition::plan(&[vec![400, 4], vec![4]], 2); // row-granular
        let err = ShardedOptimizer::new("adafactor", &part, 0);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("tensor-aligned"));
    }
}
