//! Composable update guard: RMS clipping + non-finite scrubbing.
//!
//! Adafactor's stability fix (Shazeer & Stern, §5.3 "update clipping")
//! caps the root-mean-square of each tensor's *update* — not the
//! gradient — at a threshold `d`: `update /= max(1, RMS(update)/d)`.
//! The insight is that the update is the quantity whose scale actually
//! moves parameters, and second-moment optimizers can emit huge updates
//! from stale statistics right after a loss spike even when the gradient
//! itself looks tame. [`Guard`] retrofits that rule onto every optimizer
//! in this crate (`--clip-update d`), plus a harder backstop: any update
//! element that comes out non-finite is scrubbed — the parameter reverts
//! to its pre-step value — so a single poisoned lane can never propagate
//! NaNs through a whole tensor.
//!
//! The wrapper is **stateless**: clipping and scrubbing are pure
//! functions of (pre-step params, post-step params), computed from a
//! snapshot taken around the inner step. `export_state`/`import_state`
//! delegate to the inner optimizer unchanged, so checkpoint geometry
//! (`Partition::state_slice_elems`) and the PR 5 elastic manifest format
//! are untouched — a guarded run and an unguarded run produce
//! interchangeable checkpoints. The clip/scrub counters are diagnostics,
//! reported per run, not persisted.
//!
//! Sharded caveat: for the row-split forms the guard sees only this
//! rank's owned piece of each tensor, so the clip RMS is *per piece* —
//! enabling `--clip-update` on a sharded run is stable but not
//! rank-count invariant (the scrub, being elementwise, is). The
//! engine's rank-invariant anomaly policy (`--on-anomaly`) rides the
//! collective instead; the guard is the per-rank second line.

use anyhow::Result;

use super::{Collective, Optimizer, ShardedOptimizer};
use crate::tensor::{kernels, Tensor};

/// Wraps any [`Optimizer`] with Adafactor-style RMS update clipping and
/// non-finite update scrubbing. With `clip == None` and `scrub == false`
/// the wrapper is a zero-cost pass-through (no snapshot is taken).
pub struct Guard<O> {
    inner: O,
    clip: Option<f32>,
    scrub: bool,
    /// Pre-step parameter snapshot, one buffer per guarded region,
    /// reused across steps so the steady state is allocation-free.
    snap: Vec<Vec<f32>>,
    clips: u64,
    scrubs: u64,
}

impl<O> Guard<O> {
    /// Guard `inner`, clipping each tensor's update RMS at `clip` (None
    /// = no clipping) and reverting non-finite update elements when
    /// `scrub` is set.
    pub fn new(inner: O, clip: Option<f32>, scrub: bool) -> Guard<O> {
        if let Some(d) = clip {
            assert!(d > 0.0, "clip threshold must be positive (got {d})");
        }
        Guard { inner, clip, scrub, snap: Vec::new(), clips: 0, scrubs: 0 }
    }

    /// The wrapped optimizer.
    pub fn inner(&self) -> &O {
        &self.inner
    }

    /// The wrapped optimizer, mutably (checkpoint import, shard wiring).
    pub fn inner_mut(&mut self) -> &mut O {
        &mut self.inner
    }

    /// Tensors whose update RMS was clipped, cumulative over the run.
    pub fn clips(&self) -> u64 {
        self.clips
    }

    /// Non-finite update elements reverted, cumulative over the run.
    pub fn scrubs(&self) -> u64 {
        self.scrubs
    }

    fn active(&self) -> bool {
        self.clip.is_some() || self.scrub
    }

    /// Snapshot region `i` (growing the scratch list on first use).
    fn snapshot(&mut self, i: usize, data: &[f32]) {
        if self.snap.len() <= i {
            self.snap.resize_with(i + 1, Vec::new);
        }
        self.snap[i].clear();
        self.snap[i].extend_from_slice(data);
    }

    /// Apply scrub-then-clip to one post-step region against its
    /// snapshot. Scrub first: a single NaN lane would otherwise poison
    /// the clip RMS and turn the whole region's update to garbage.
    fn guard_region(&mut self, i: usize, new: &mut [f32]) {
        let old = &self.snap[i];
        debug_assert_eq!(old.len(), new.len());
        if self.scrub && !kernels::all_finite(new) {
            for (n, &o) in new.iter_mut().zip(old) {
                if !n.is_finite() {
                    *n = o;
                    self.scrubs += 1;
                }
            }
        }
        let Some(d) = self.clip else { return };
        let mut sq = 0.0f64;
        for (&n, &o) in new.iter().zip(old) {
            let u = (n - o) as f64;
            sq += u * u;
        }
        // lint: allow(r6): f64 accumulation is deliberate; the final rms fits f32 fine
        let rms = (sq / new.len().max(1) as f64).sqrt() as f32;
        if rms > d {
            // Adafactor Eq. (clipped update): u / max(1, RMS(u)/d).
            let f = d / rms;
            for (n, &o) in new.iter_mut().zip(old) {
                *n = o + (*n - o) * f;
            }
            self.clips += 1;
        }
    }
}

impl<O: Optimizer> Optimizer for Guard<O> {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        if !self.active() {
            return self.inner.step(params, grads, lr);
        }
        for (i, p) in params.iter().enumerate() {
            self.snapshot(i, p.data());
        }
        self.inner.step(params, grads, lr);
        for (i, p) in params.iter_mut().enumerate() {
            self.guard_region(i, p.data_mut());
        }
    }

    fn state_overhead_bytes(&self) -> usize {
        self.inner.state_overhead_bytes()
    }

    fn aliases_grad_slot(&self) -> bool {
        self.inner.aliases_grad_slot()
    }

    fn export_state(&self, out: &mut Vec<f32>) {
        self.inner.export_state(out)
    }

    fn import_state(&mut self, shapes: &[Vec<usize>], data: &[f32], step: usize) -> Result<()> {
        self.inner.import_state(shapes, data, step)
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

impl Guard<ShardedOptimizer> {
    /// Guarded sharded update: snapshot this rank's owned piece of each
    /// tensor, run the inner collective step, then scrub/clip exactly
    /// those regions. Mirrors [`ShardedOptimizer::step_collective`].
    pub fn step_collective(
        &mut self,
        params: &mut [Tensor],
        grads: &[Tensor],
        lr: f32,
        coll: &mut dyn Collective,
    ) {
        if !self.active() {
            return self.inner.step_collective(params, grads, lr, coll);
        }
        let pieces = self.inner.pieces().to_vec();
        for (i, pc) in pieces.iter().enumerate() {
            self.snapshot(i, &params[pc.tensor].data()[pc.local.clone()]);
        }
        self.inner.step_collective(params, grads, lr, coll);
        for (i, pc) in pieces.iter().enumerate() {
            // Split the borrow: pull the owned window out of the tensor.
            let t = params[pc.tensor].data_mut();
            let local = pc.local.clone();
            self.guard_region(i, &mut t[local]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{by_name, testutil, LocalCollective};
    use crate::shard::Partition;

    /// Test double: adds a caller-chosen delta to every parameter.
    struct FixedDelta(Vec<f32>);

    impl Optimizer for FixedDelta {
        fn step(&mut self, params: &mut [Tensor], _grads: &[Tensor], _lr: f32) {
            let mut i = 0;
            for p in params.iter_mut() {
                for x in p.data_mut() {
                    *x += self.0[i % self.0.len()];
                    i += 1;
                }
            }
        }
        fn state_overhead_bytes(&self) -> usize {
            0
        }
        fn export_state(&self, _out: &mut Vec<f32>) {}
        fn import_state(&mut self, _s: &[Vec<usize>], _d: &[f32], _step: usize) -> Result<()> {
            Ok(())
        }
        fn name(&self) -> &'static str {
            "fixed-delta"
        }
    }

    #[test]
    fn clip_caps_update_rms_at_threshold() {
        // Update (3, 4) per pair: RMS = sqrt((9+16)/2) = 3.5355…
        let mut params = vec![Tensor::zeros(&[2])];
        let grads = vec![Tensor::zeros(&[2])];
        let mut g = Guard::new(FixedDelta(vec![3.0, 4.0]), Some(1.0), false);
        g.step(&mut params, &grads, 0.0);
        let rms = (params[0].data().iter().map(|x| (x * x) as f64).sum::<f64>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-6, "clipped RMS {rms} != d");
        // Direction preserved: elements stay in 3:4 ratio.
        let d = params[0].data();
        assert!((d[0] / d[1] - 0.75).abs() < 1e-6);
        assert_eq!((g.clips(), g.scrubs()), (1, 0));

        // Below the threshold nothing is touched.
        let mut params = vec![Tensor::zeros(&[2])];
        let mut g = Guard::new(FixedDelta(vec![0.3, 0.4]), Some(1.0), false);
        g.step(&mut params, &grads, 0.0);
        assert_eq!(params[0].data(), &[0.3, 0.4]);
        assert_eq!(g.clips(), 0);
    }

    #[test]
    fn scrub_reverts_only_the_non_finite_lanes() {
        let mut params = vec![Tensor::from_fn(&[4], |i| i as f32)];
        let grads = vec![Tensor::zeros(&[4])];
        let mut g =
            Guard::new(FixedDelta(vec![1.0, f32::NAN, f32::INFINITY, 1.0]), None, true);
        g.step(&mut params, &grads, 0.0);
        // Lanes 1, 2 got poisoned and reverted; lanes 0, 3 kept +1.0.
        assert_eq!(params[0].data(), &[1.0, 1.0, 2.0, 4.0]);
        assert_eq!((g.clips(), g.scrubs()), (0, 2));
    }

    #[test]
    fn disabled_guard_is_a_transparent_pass_through() {
        let shapes = vec![vec![6, 3], vec![4]];
        let (params0, grads) = testutil::fixture(&shapes, 3);
        let (mut pa, mut pb) = (params0.clone(), params0);
        let mut bare = by_name("alada", &shapes).unwrap();
        let mut guarded = Guard::new(by_name("alada", &shapes).unwrap(), None, false);
        for _ in 0..4 {
            bare.step(&mut pa, &grads, 1e-2);
            guarded.step(&mut pb, &grads, 1e-2);
        }
        assert_eq!(pa, pb, "pass-through must be bit-identical");
        assert_eq!(guarded.name(), "alada");
        let (mut a, mut b) = (Vec::new(), Vec::new());
        bare.export_state(&mut a);
        guarded.export_state(&mut b);
        assert_eq!(a, b, "state export delegates to the inner optimizer");
    }

    #[test]
    fn sharded_guard_scrubs_owned_pieces() {
        let shapes = vec![vec![8, 4], vec![5]];
        let part = Partition::plan_for("alada", &shapes, 1);
        let sharded = ShardedOptimizer::new("alada", &part, 0).unwrap();
        let mut g = Guard::new(sharded, None, true);
        let (mut params, mut grads) = testutil::fixture(&shapes, 11);
        grads[0].data_mut()[5] = f32::NAN; // poisons the whole update row
        let before = params.clone();
        g.step_collective(&mut params, &grads, 1e-2, &mut LocalCollective);
        for p in &params {
            assert!(kernels::all_finite(p.data()), "scrub left a non-finite parameter");
        }
        assert!(g.scrubs() > 0, "the poisoned lanes were scrubbed");
        assert_ne!(params, before, "clean lanes still stepped");
    }
}
