//! Config system: typed run configuration loadable from a TOML-subset
//! file, overridable from CLI flags.
//!
//! `alada train --config runs/my_run.toml` and the experiment drivers
//! share `RunConfig`. The parser (toml.rs) covers the subset a training
//! config needs: `[sections]`, `key = value` with strings, numbers,
//! booleans, and flat arrays — hand-rolled because the offline registry
//! has no serde/toml.

// clippy's disallowed-methods backs up lint rule r3 (no wall-clock in
// step paths); wall-clock seeds the default run id only, never the math.
#![allow(clippy::disallowed_methods)]

pub mod toml;

use anyhow::{anyhow, Result};

pub use toml::TomlDoc;

/// One training run, fully specified.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub task: String,
    pub size: String,
    pub opt: String,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub dataset: usize,
    pub schedule: String,
    pub artifact_dir: String,
    pub out_dir: String,
    pub record_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            task: "lm".into(),
            size: "small".into(),
            opt: "alada".into(),
            steps: 300,
            lr: 1e-3,
            seed: 0,
            dataset: 0,
            schedule: String::new(), // empty = diminishing over `steps`
            artifact_dir: "artifacts".into(),
            out_dir: "results".into(),
            record_every: 10,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file ([run] section; missing keys keep defaults).
    pub fn from_file(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("config {path:?}: {e}"))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("config {path:?}: {e}"))?;
        let mut cfg = RunConfig::default();
        let get = |k: &str| doc.get("run", k);
        if let Some(v) = get("task") {
            cfg.task = v.as_str().ok_or_else(|| anyhow!("run.task must be a string"))?.into();
        }
        if let Some(v) = get("size") {
            cfg.size = v.as_str().ok_or_else(|| anyhow!("run.size must be a string"))?.into();
        }
        if let Some(v) = get("opt") {
            cfg.opt = v.as_str().ok_or_else(|| anyhow!("run.opt must be a string"))?.into();
        }
        if let Some(v) = get("steps") {
            cfg.steps = v.as_f64().ok_or_else(|| anyhow!("run.steps must be a number"))? as usize;
        }
        if let Some(v) = get("lr") {
            cfg.lr = v.as_f64().ok_or_else(|| anyhow!("run.lr must be a number"))? as f32;
        }
        if let Some(v) = get("seed") {
            cfg.seed = v.as_f64().unwrap_or(0.0) as u64;
        }
        if let Some(v) = get("dataset") {
            cfg.dataset = v.as_f64().unwrap_or(0.0) as usize;
        }
        if let Some(v) = get("schedule") {
            cfg.schedule = v.as_str().unwrap_or("").into();
        }
        if let Some(v) = get("artifacts") {
            cfg.artifact_dir = v.as_str().unwrap_or("artifacts").into();
        }
        if let Some(v) = get("record_every") {
            cfg.record_every = v.as_f64().unwrap_or(10.0) as usize;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if !["lm", "cls", "mt"].contains(&self.task.as_str()) {
            return Err(anyhow!("task must be lm|cls|mt, got {:?}", self.task));
        }
        if !["tiny", "small", "base"].contains(&self.size.as_str()) {
            return Err(anyhow!("size must be tiny|small|base, got {:?}", self.size));
        }
        if self.steps == 0 {
            return Err(anyhow!("steps must be > 0"));
        }
        if !(self.lr > 0.0) {
            return Err(anyhow!("lr must be > 0, got {}", self.lr));
        }
        Ok(())
    }

    /// The schedule this run uses (paper default: diminishing η₀·(1−t/T)).
    pub fn make_schedule(&self) -> Result<crate::optim::Schedule> {
        if self.schedule.is_empty() {
            Ok(crate::optim::Schedule::Diminishing { eta0: self.lr, total: self.steps })
        } else {
            crate::optim::Schedule::parse(&self.schedule).map_err(|e| anyhow!(e))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(content: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "alada_cfg_{}.toml",
            std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
        ));
        std::fs::write(&p, content).unwrap();
        p
    }

    #[test]
    fn loads_full_config() {
        let p = write_tmp(
            "# a run\n[run]\ntask = \"mt\"\nsize = \"tiny\"\nopt = \"adam\"\n\
             steps = 50\nlr = 0.002\nseed = 7\ndataset = 3\nschedule = \"const:0.001\"\n",
        );
        let cfg = RunConfig::from_file(p.to_str().unwrap()).unwrap();
        assert_eq!(cfg.task, "mt");
        assert_eq!(cfg.opt, "adam");
        assert_eq!(cfg.steps, 50);
        assert!((cfg.lr - 0.002).abs() < 1e-9);
        assert_eq!(cfg.dataset, 3);
        assert_eq!(
            cfg.make_schedule().unwrap(),
            crate::optim::Schedule::Constant { eta0: 0.001 }
        );
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn defaults_fill_missing_keys() {
        let p = write_tmp("[run]\ntask = \"cls\"\n");
        let cfg = RunConfig::from_file(p.to_str().unwrap()).unwrap();
        assert_eq!(cfg.task, "cls");
        assert_eq!(cfg.size, "small");
        assert_eq!(cfg.steps, 300);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_bad_values() {
        let p = write_tmp("[run]\ntask = \"bogus\"\n");
        assert!(RunConfig::from_file(p.to_str().unwrap()).is_err());
        std::fs::remove_file(p).ok();
        assert!(RunConfig { steps: 0, ..Default::default() }.validate().is_err());
        assert!(RunConfig { lr: -1.0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn default_schedule_is_paper_diminishing() {
        let cfg = RunConfig::default();
        match cfg.make_schedule().unwrap() {
            crate::optim::Schedule::Diminishing { eta0, total } => {
                assert_eq!(eta0, cfg.lr);
                assert_eq!(total, cfg.steps);
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
