//! TOML-subset parser for run configs.
//!
//! Supported: `[section]` headers, `key = value` lines, `#` comments,
//! values: basic strings, integers/floats, booleans, flat arrays of the
//! same. Enough for training configs; deliberately not a full TOML
//! implementation (no dotted keys, no multi-line strings, no dates).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: section → key → value. Top-level keys live in "".
#[derive(Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (i, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: i + 1, msg: msg.to_string() };
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| err("unterminated [section]"))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let value = parse_value(v.trim()).map_err(|m| err(&m))?;
                doc.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(k.trim().to_string(), value);
            } else {
                return Err(err("expected `key = value` or `[section]`"));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // a `#` outside a string starts a comment
    let mut in_str = false;
    for (idx, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..idx],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                out.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value {s:?}"))
}

/// Split an array body on commas that are not inside strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (idx, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..idx]);
                start = idx + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            "top = 1\n[run]\ntask = \"lm\" # comment\nlr = 1e-3\nflag = true\nn = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&TomlValue::Num(1.0)));
        assert_eq!(doc.get("run", "task").unwrap().as_str(), Some("lm"));
        assert_eq!(doc.get("run", "lr").unwrap().as_f64(), Some(1e-3));
        assert_eq!(doc.get("run", "flag").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("run", "n").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn parses_arrays() {
        let doc = TomlDoc::parse("lrs = [1e-3, 2e-3, 4e-3]\nnames = [\"a,b\", \"c\"]\n").unwrap();
        match doc.get("", "lrs").unwrap() {
            TomlValue::Arr(v) => assert_eq!(v.len(), 3),
            other => panic!("{other:?}"),
        }
        match doc.get("", "names").unwrap() {
            TomlValue::Arr(v) => {
                assert_eq!(v[0].as_str(), Some("a,b"));
                assert_eq!(v[1].as_str(), Some("c"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("x = \"open\n").is_err());
    }
}
