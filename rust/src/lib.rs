//! Alada: alternating adaptation of momentum for memory-efficient matrix
//! optimization — full-system reproduction.
//!
//! Three-layer architecture:
//! * L1 — Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * L2 — JAX model + in-graph optimizers, AOT-lowered to HLO text
//! * L3 — this crate: training framework, PJRT runtime, data pipeline,
//!   experiment coordinator, pure-Rust optimizer substrate.

// The library is entirely safe Rust; the binary's lone signal-FFI site
// carries its own scoped allow (see main.rs, lint rule r8).
#![deny(unsafe_code)]

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod data;
pub mod lint;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod tensor;
pub mod train;
pub mod util;
