//! Alada: alternating adaptation of momentum for memory-efficient matrix
//! optimization — full-system reproduction.
//!
//! Three-layer architecture:
//! * L1 — Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * L2 — JAX model + in-graph optimizers, AOT-lowered to HLO text
//! * L3 — this crate: training framework, PJRT runtime, data pipeline,
//!   experiment coordinator, pure-Rust optimizer substrate.

// Safe Rust throughout, with two audited exceptions that carry their
// own scoped allows under lint rule r8's SAFETY-comment discipline: the
// SIMD kernel backends (`tensor/kernels/{avx2,neon}.rs`, intrinsics
// installed only after runtime feature detection) and the binary's lone
// signal-FFI site (main.rs).
#![deny(unsafe_code)]

pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod data;
pub mod lint;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod tensor;
pub mod train;
pub mod util;
