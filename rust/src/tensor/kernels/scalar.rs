//! The scalar lane-unrolled backend — the correctness oracle.
//!
//! These are the original autovectorizer-friendly loops: reductions use
//! `chunks_exact` with a fixed array of [`LANES`] independent
//! accumulators (the dependency chain LLVM needs broken before it will
//! emit SIMD adds), elementwise updates are branch-free single passes
//! over zipped slices. Every intrinsic backend is defined as
//! "bit-identical to this module" (see the module docs in `mod.rs` for
//! the association-order contract, and rust/tests/simd_parity.rs for
//! the pin).
//!
//! Reduction kernels *reassociate* relative to the naive sequential sum
//! (~1e-7 relative noise) — the trajectory-level contracts in
//! rust/tests/ are all tolerance-based exactly so that kernel-level
//! reshaping like this stays legal. Elementwise kernels keep the
//! original expression order and are bit-identical to the loops they
//! replaced.

use super::{check_f32_aligned, check_same_len, LANES};

/// Fused finite scan: true iff every element is finite (no NaN/±Inf).
/// One multiply-add pass — `x·0` is ±0 for finite x and NaN for NaN/Inf,
/// so the lane sums stay exactly 0.0 iff nothing non-finite was seen.
/// This is the numerical sentinel the shard engine runs over every
/// reduced gradient buffer each step, so it must cost a fraction of the
/// update kernels it guards (same LANES unrolling, no branches).
#[inline]
pub fn all_finite(x: &[f32]) -> bool {
    check_f32_aligned!(x);
    let split = x.len() - x.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for c in x[..split].chunks_exact(LANES) {
        for l in 0..LANES {
            acc[l] += c[l] * 0.0;
        }
    }
    let mut s = 0.0f32;
    for &l in &acc {
        s += l;
    }
    for &v in &x[split..] {
        s += v * 0.0;
    }
    s == 0.0
}

/// Plain sum with LANES independent accumulators — the one blessed f32
/// reduction (see the `mod.rs` shim doc and lint rule r2).
#[inline]
pub fn sum(x: &[f32]) -> f32 {
    check_f32_aligned!(x);
    let split = x.len() - x.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for c in x[..split].chunks_exact(LANES) {
        for l in 0..LANES {
            acc[l] += c[l];
        }
    }
    let mut s = 0.0f32;
    for &l in &acc {
        s += l;
    }
    for &v in &x[split..] {
        s += v;
    }
    s
}

/// Dot product with LANES independent accumulators.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    check_same_len!(a, b);
    check_f32_aligned!(a, b);
    let split = a.len() - a.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for (xa, xb) in a[..split].chunks_exact(LANES).zip(b[..split].chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = 0.0f32;
    for &l in &acc {
        s += l;
    }
    for (x, y) in a[split..].iter().zip(&b[split..]) {
        s += x * y;
    }
    s
}

/// Σ_j (m_j·s)²·q_j — Alada's even-phase row projection (V q at row i
/// with V = (M·bc1)² recomputed in-register, never materialised).
#[inline]
pub fn sq_dot_scaled(m: &[f32], q: &[f32], s: f32) -> f32 {
    check_same_len!(m, q);
    check_f32_aligned!(m, q);
    let split = m.len() - m.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for (xm, xq) in m[..split].chunks_exact(LANES).zip(q[..split].chunks_exact(LANES)) {
        for l in 0..LANES {
            let v = xm[l] * s;
            acc[l] += v * v * xq[l];
        }
    }
    let mut out = 0.0f32;
    for &l in &acc {
        out += l;
    }
    for (x, q) in m[split..].iter().zip(&q[split..]) {
        let v = x * s;
        out += v * v * q;
    }
    out
}

/// acc_j += (m_j·s)²·w — Alada's odd-phase column reduction (Vᵀp), one
/// row's contribution.
#[inline]
pub fn sq_axpy_scaled(acc: &mut [f32], m: &[f32], s: f32, w: f32) {
    check_same_len!(acc, m);
    for (a, &x) in acc.iter_mut().zip(m) {
        let v = x * s;
        *a += v * v * w;
    }
}

/// dst = a·dst + b·src — the EMA workhorse (`Tensor::ema_inplace`).
#[inline]
pub fn ema(dst: &mut [f32], src: &[f32], a: f32, b: f32) {
    check_same_len!(dst, src);
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = a * *d + b * s;
    }
}

/// dst = β·dst + (1−β)·src/denom — the factored-moment EMA of
/// Adafactor/CAME/Alada (row/col means enter scaled by the reduction
/// denominator; expression order matches the scalar loops exactly).
#[inline]
pub fn factor_ema(dst: &mut [f32], src: &[f32], beta: f32, denom: f32) {
    check_same_len!(dst, src);
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = beta * *d + (1.0 - beta) * s / denom;
    }
}

/// y += a·x.
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    check_same_len!(y, x);
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// x *= s.
#[inline]
pub fn scale(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

/// Elementwise correctly-rounded divide (NOT multiply-by-reciprocal):
/// `x[i] /= d`. Division by a small integer recovers an exact multiple
/// exactly — `(k·g)/k == g` whenever `k·g` was computed exactly — which
/// is what makes the gradient mean of identical per-rank contributions
/// rank-count-invariant (the elastic-checkpoint parity contract; see
/// shard/collective.rs). `x * (1/d)` does NOT have this property for
/// non-power-of-two `d`.
pub fn divide(x: &mut [f32], d: f32) {
    for v in x.iter_mut() {
        *v /= d;
    }
}

/// x += y elementwise — the collective's segment-sum building block
/// (the bucket accumulation in `Comm::reduce_bucket`). Plain
/// independent per-element adds, so any vector width preserves
/// bit-identity; the fixed reduction-tree *order* lives in the
/// collective, not here.
#[inline]
pub fn add_assign(x: &mut [f32], y: &[f32]) {
    check_same_len!(x, y);
    for (a, &b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

/// Alada descent over one row (both phases): with û_j = max(p_i·q_j −
/// sub, 0)·bc2_inv and m̂_j = m_j·bc1, x_j −= lr·m̂_j/√(û_j + ε).
/// Branch-free (max compiles to a select), single fused pass.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn alada_descent_row(
    x: &mut [f32],
    m: &[f32],
    q: &[f32],
    pi: f32,
    bc1: f32,
    sub: f32,
    bc2_inv: f32,
    eps: f32,
    lr: f32,
) {
    check_same_len!(x, m, q);
    for ((xj, &mj), &qj) in x.iter_mut().zip(m).zip(q) {
        let u_hat = (pi * qj - sub).max(0.0) * bc2_inv;
        let m_hat = mj * bc1;
        *xj -= lr * m_hat / (u_hat + eps).sqrt();
    }
}

/// Fused Adam element update: EMA both moments and descend in one pass
/// (the three separate loops it replaces cost two extra sweeps of
/// memory traffic per tensor).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn adam_update(
    x: &mut [f32],
    m: &mut [f32],
    u: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, m, u, g);
    for (((xj, mj), uj), &gj) in x.iter_mut().zip(m.iter_mut()).zip(u.iter_mut()).zip(g) {
        *mj = b1 * *mj + (1.0 - b1) * gj;
        *uj = b2 * *uj + (1.0 - b2) * gj * gj;
        let m_hat = *mj * bc1;
        let u_hat = *uj * bc2;
        *xj -= lr * m_hat / (u_hat.sqrt() + eps);
    }
}

/// Row/column accumulation of V = g² + ε (Adafactor/CAME first pass):
/// csum_j += v_j, returns Σ_j v_j via LANES accumulators.
#[inline]
pub fn sq_eps_rowcol(row: &[f32], csum: &mut [f32], eps: f32) -> f32 {
    check_same_len!(row, csum);
    check_f32_aligned!(row, csum);
    let split = row.len() - row.len() % LANES;
    let mut acc = [0.0f32; LANES];
    {
        let (rh, ch) = (&row[..split], &mut csum[..split]);
        for (rc, cc) in rh.chunks_exact(LANES).zip(ch.chunks_exact_mut(LANES)) {
            for l in 0..LANES {
                let v = rc[l] * rc[l] + eps;
                cc[l] += v;
                acc[l] += v;
            }
        }
    }
    let mut s = 0.0f32;
    for &l in &acc {
        s += l;
    }
    for (&x, c) in row[split..].iter().zip(&mut csum[split..]) {
        let v = x * x + eps;
        *c += v;
        s += v;
    }
    s
}

/// Adafactor descent over one row: u_j = ri·(c_j·bc)·inv_mean,
/// x_j −= lr·g_j/(√u_j + ε).
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn factored_descent_row(
    x: &mut [f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, g, c);
    for ((xj, &gj), &cj) in x.iter_mut().zip(g).zip(c) {
        let u = ri * (cj * bc) * inv_mean;
        *xj -= lr * gj / (u.sqrt() + eps);
    }
}

/// CAME instability pass over one row: û_j = g_j/(√(ri·(c_j·bc)·inv) + ε),
/// v_j = (m_j − û_j)² + ε; accumulates v into inst_c and returns Σ_j v_j.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn came_instability_row(
    m: &[f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    eps: f32,
    inst_c: &mut [f32],
) -> f32 {
    check_same_len!(m, g, c, inst_c);
    check_f32_aligned!(m, g, c, inst_c);
    let split = m.len() - m.len() % LANES;
    let mut acc = [0.0f32; LANES];
    {
        let (mh, gh, ch, ih) =
            (&m[..split], &g[..split], &c[..split], &mut inst_c[..split]);
        for (((mc, gc), cc), ic) in mh
            .chunks_exact(LANES)
            .zip(gh.chunks_exact(LANES))
            .zip(ch.chunks_exact(LANES))
            .zip(ih.chunks_exact_mut(LANES))
        {
            for l in 0..LANES {
                let u = ri * (cc[l] * bc) * inv_mean;
                let u_hat = gc[l] / (u.sqrt() + eps);
                let d = mc[l] - u_hat;
                let v = d * d + eps;
                ic[l] += v;
                acc[l] += v;
            }
        }
    }
    let mut s = 0.0f32;
    for &l in &acc {
        s += l;
    }
    for i in split..m.len() {
        let u = ri * (c[i] * bc) * inv_mean;
        let u_hat = g[i] / (u.sqrt() + eps);
        let d = m[i] - u_hat;
        let v = d * d + eps;
        inst_c[i] += v;
        s += v;
    }
    s
}

/// CAME confidence-scaled descent over one row:
/// x_j −= lr·m_j/(√(uri·uc_j·inv) + ε).
#[inline]
pub fn came_descent_row(
    x: &mut [f32],
    m: &[f32],
    uc: &[f32],
    uri: f32,
    inv: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, m, uc);
    for ((xj, &mj), &ucj) in x.iter_mut().zip(m).zip(uc) {
        let s = (uri * ucj * inv).sqrt() + eps;
        *xj -= lr * mj / s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        (a, b)
    }

    #[test]
    fn all_finite_flags_every_non_finite_class_at_any_position() {
        for n in [0usize, 1, 7, 8, 9, 16, 31] {
            let (clean, _) = vecs(n, 5 + n as u64);
            assert!(all_finite(&clean), "n={n}: clean data must pass");
            for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                for pos in [0, n / 2, n.saturating_sub(1)] {
                    if n == 0 {
                        continue;
                    }
                    let mut v = clean.clone();
                    v[pos] = bad;
                    assert!(!all_finite(&v), "n={n} pos={pos} bad={bad}");
                }
            }
        }
        // negative zeros and subnormals are finite
        assert!(all_finite(&[-0.0, f32::MIN_POSITIVE / 2.0, f32::MAX, f32::MIN]));
    }

    #[test]
    fn dot_matches_naive_across_lengths() {
        for n in [0usize, 1, 3, 7, 8, 9, 16, 31, 100] {
            let (a, b) = vecs(n, n as u64 + 1);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - naive).abs() <= 1e-5 * (1.0 + naive.abs()), "n={n}: {got} vs {naive}");
        }
    }

    #[test]
    fn dot_is_deterministic() {
        let (a, b) = vecs(53, 9);
        assert_eq!(dot(&a, &b).to_bits(), dot(&a, &b).to_bits());
    }

    #[test]
    fn sq_dot_scaled_matches_naive() {
        for n in [1usize, 5, 8, 21] {
            let (m, q) = vecs(n, 70 + n as u64);
            let s = 1.7f32;
            let naive: f32 = m.iter().zip(&q).map(|(x, y)| (x * s) * (x * s) * y).sum();
            let got = sq_dot_scaled(&m, &q, s);
            assert!((got - naive).abs() <= 1e-4 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn elementwise_kernels_match_scalar_loops_exactly() {
        let (m, g) = vecs(19, 3);
        // ema
        let mut a = m.clone();
        let mut b = m.clone();
        ema(&mut a, &g, 0.9, 0.1);
        for (x, &gi) in b.iter_mut().zip(&g) {
            *x = 0.9 * *x + 0.1 * gi;
        }
        assert_eq!(a, b);
        // axpy
        let mut a = m.clone();
        let mut b = m.clone();
        axpy(&mut a, &g, -0.3);
        for (x, &gi) in b.iter_mut().zip(&g) {
            *x += -0.3 * gi;
        }
        assert_eq!(a, b);
        // factor_ema
        let mut a = m.clone();
        let mut b = m.clone();
        factor_ema(&mut a, &g, 0.99, 12.0);
        for (x, &gi) in b.iter_mut().zip(&g) {
            *x = 0.99 * *x + (1.0 - 0.99) * gi / 12.0;
        }
        assert_eq!(a, b);
        // add_assign
        let mut a = m.clone();
        let mut b = m.clone();
        add_assign(&mut a, &g);
        for (x, &gi) in b.iter_mut().zip(&g) {
            *x += gi;
        }
        assert_eq!(a, b);
    }

    #[test]
    fn adam_update_matches_three_pass_reference() {
        let n = 23;
        let (x0, g) = vecs(n, 11);
        let (m0, u0) = {
            let (a, b) = vecs(n, 12);
            (a, b.iter().map(|v| v * v).collect::<Vec<f32>>())
        };
        let (b1, b2, bc1, bc2, lr, eps) = (0.9f32, 0.999f32, 1.1f32, 1.3f32, 1e-2f32, 1e-8f32);
        let (mut x, mut m, mut u) = (x0.clone(), m0.clone(), u0.clone());
        adam_update(&mut x, &mut m, &mut u, &g, b1, b2, bc1, bc2, lr, eps);
        // reference: the original three separate sweeps
        let (mut xr, mut mr, mut ur) = (x0, m0, u0);
        for (mj, &gj) in mr.iter_mut().zip(&g) {
            *mj = b1 * *mj + (1.0 - b1) * gj;
        }
        for (uj, &gj) in ur.iter_mut().zip(&g) {
            *uj = b2 * *uj + (1.0 - b2) * gj * gj;
        }
        for ((xj, &mj), &uj) in xr.iter_mut().zip(&mr).zip(&ur) {
            *xj -= lr * (mj * bc1) / ((uj * bc2).sqrt() + eps);
        }
        assert_eq!(x, xr);
        assert_eq!(m, mr);
        assert_eq!(u, ur);
    }

    #[test]
    fn sq_eps_rowcol_matches_naive() {
        for n in [1usize, 8, 13, 40] {
            let (row, _) = vecs(n, 21 + n as u64);
            let mut csum = vec![0.5f32; n];
            let mut csum_ref = vec![0.5f32; n];
            let got = sq_eps_rowcol(&row, &mut csum, 1e-8);
            let mut want = 0.0f32;
            for (c, &x) in csum_ref.iter_mut().zip(&row) {
                let v = x * x + 1e-8;
                *c += v;
                want += v;
            }
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "n={n}");
            for (a, b) in csum.iter().zip(&csum_ref) {
                assert_eq!(a.to_bits(), b.to_bits(), "csum must be exact");
            }
        }
    }

    #[test]
    fn descent_rows_move_against_the_gradient() {
        let n = 17;
        let m = vec![1.0f32; n];
        let q = vec![0.5f32; n];
        let mut x = vec![0.0f32; n];
        alada_descent_row(&mut x, &m, &q, 0.5, 1.0, 0.0, 1.0, 1e-8, 0.1);
        assert!(x.iter().all(|&v| v < 0.0), "positive m must push x down");
        let mut x2 = vec![0.0f32; n];
        factored_descent_row(&mut x2, &m, &q, 1.0, 1.0, 1.0, 0.1, 1e-8);
        assert!(x2.iter().all(|&v| v < 0.0));
        let mut x3 = vec![0.0f32; n];
        came_descent_row(&mut x3, &m, &q, 1.0, 1.0, 0.1, 1e-8);
        assert!(x3.iter().all(|&v| v < 0.0));
    }

    #[test]
    fn came_instability_row_matches_naive() {
        let n = 21;
        let (m, g) = vecs(n, 31);
        let c = vec![0.7f32; n];
        let (ri, bc, inv, eps) = (0.8f32, 1.2f32, 0.9f32, 1e-8f32);
        let mut inst = vec![0.0f32; n];
        let got = came_instability_row(&m, &g, &c, ri, bc, inv, eps, &mut inst);
        let mut want = 0.0f32;
        let mut inst_ref = vec![0.0f32; n];
        for j in 0..n {
            let u = ri * (c[j] * bc) * inv;
            let u_hat = g[j] / (u.sqrt() + eps);
            let d = m[j] - u_hat;
            let v = d * d + eps;
            inst_ref[j] += v;
            want += v;
        }
        assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()));
        for (a, b) in inst.iter().zip(&inst_ref) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
