//! Vectorized flat-slice kernels for the optimizer hot loops, behind a
//! runtime-dispatched backend table.
//!
//! Every per-element loop that shows up in a profile of the pure-Rust
//! substrate lives here: Alada's fused even/odd descent passes, the
//! Adam/Adafactor/CAME element updates, the `tensor::ops` mat-vec
//! building blocks, and the collective's segment-sum. Three backends
//! implement the same kernel set:
//!
//! * [`scalar`] — the lane-unrolled safe-Rust oracle. Every other
//!   backend is defined as "bit-identical to this".
//! * [`avx2`] (`x86_64`) — `_mm256_*` intrinsics, one 8 × f32 register
//!   per accumulator chunk, installed only when
//!   `is_x86_feature_detected!("avx2")` holds at startup.
//! * [`neon`] (`aarch64`) — `v*q_f32` intrinsics, two 4 × f32 registers
//!   per chunk, installed only when NEON is detected.
//!
//! # Dispatch
//!
//! The backend is chosen ONCE per process: the first kernel call reads
//! `ALADA_SIMD` (`auto` | `scalar` | `avx2` | `neon`; unset = `auto`),
//! probes the CPU, and caches a [`Kernels`] table of plain function
//! pointers in a `OnceLock`. Requests for an unavailable ISA (or an
//! unknown value) fall back to `scalar` and record a note that
//! `alada features` and the shard-train/serve startup banners surface —
//! a dispatch decision is always attributable. The public free
//! functions below are thin `#[inline]` shims through the cached table,
//! so call sites are unchanged from the pre-dispatch module.
//!
//! # The association-order contract
//!
//! Determinism: every kernel is a pure function of its inputs with a
//! fixed association order, so replacing a scalar loop with a kernel —
//! or a scalar kernel with a SIMD twin — keeps runs bit-for-bit
//! reproducible. The contract every backend MUST preserve:
//!
//! * Reductions split the input at `len - len % LANES` and keep
//!   [`LANES`] = 8 *independent* accumulators: accumulator lane `l`
//!   sums elements `i` with `i % LANES == l` of the head, in index
//!   order. One AVX2 register (or two NEON registers, low half =
//!   lanes 0–3) maps 1:1 onto the scalar `[f32; LANES]` array, and
//!   vertical SIMD adds reproduce the per-lane sums exactly.
//! * The horizontal combine is the same *sequential* fold the scalar
//!   path runs: `s = ((((0 + acc[0]) + acc[1]) + …) + acc[7])` — SIMD
//!   backends store the register(s) to an array and fold in lane
//!   order; no tree reduction, no shuffles that reassociate.
//! * The tail (`len % LANES` trailing elements) is folded into `s`
//!   sequentially after the lanes, in index order.
//! * Elementwise kernels keep the exact per-element expression order
//!   of the scalar loop (e.g. Adam's `b2*u + ((1-b2)*g)*g`), and never
//!   use FMA: fused multiply-adds round once where the scalar path
//!   rounds twice, which would break bit-identity.
//! * Only correctly-rounded IEEE 754 operations are used (`+ - * /`
//!   and `sqrt` are correctly rounded in both `_mm256_*` and `v*q_f32`
//!   forms), so per-lane results equal the scalar results bit-for-bit.
//!
//! rust/tests/simd_parity.rs pins `simd == scalar` bit-for-bit for
//! every dispatched kernel at adversarial lengths and values; the
//! shard-parity / elastic-resume / fault-injection suites therefore
//! hold unchanged under every backend, with no tolerance adjustments.

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "aarch64")]
pub mod neon;

use std::sync::OnceLock;

/// Accumulator lanes for reductions: 8 × f32 = one AVX2 register (and
/// exactly two NEON registers). Part of the public determinism
/// contract — changing it changes every reduction's association order.
pub const LANES: usize = 8;

/// Debug-build precondition: every listed slice has the same length as
/// the first. Shared by the scalar and SIMD backends so a miscalled
/// kernel fails loudly in debug and stays branch-free in release.
macro_rules! check_same_len {
    ($a:expr $(, $b:expr)+) => {
        $( debug_assert_eq!(
            $a.len(),
            $b.len(),
            "kernel precondition: slice lengths must match",
        ); )+
    };
}

/// Debug-build precondition: a slice the backend will walk with
/// word-at-a-time loads is f32-aligned (always true for a `&[f32]`,
/// asserted anyway per the checked-ops discipline — unaligned data
/// would mean the slice itself is forged).
macro_rules! check_f32_aligned {
    ($( $a:expr ),+) => {
        $( debug_assert_eq!(
            $a.as_ptr() as usize % std::mem::align_of::<f32>(),
            0,
            "kernel precondition: slice must be f32-aligned",
        ); )+
    };
}

pub(crate) use {check_f32_aligned, check_same_len};

/// Which kernel implementation a [`Kernels`] table carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The lane-unrolled safe-Rust oracle (always available).
    Scalar,
    /// `x86_64` AVX2 intrinsics (runtime-detected).
    Avx2,
    /// `aarch64` NEON intrinsics (runtime-detected).
    Neon,
}

impl Backend {
    /// The name the CLI/env override and the bench JSON use.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

/// One backend's complete kernel set as plain function pointers — the
/// unit of dispatch. Fields are public so the parity tests can drive
/// each backend directly and pin that a forced-`scalar` selection
/// routes every kernel through the oracle.
#[derive(Clone, Copy)]
pub struct Kernels {
    pub backend: Backend,
    pub all_finite: fn(&[f32]) -> bool,
    pub sum: fn(&[f32]) -> f32,
    pub dot: fn(&[f32], &[f32]) -> f32,
    pub sq_dot_scaled: fn(&[f32], &[f32], f32) -> f32,
    pub sq_axpy_scaled: fn(&mut [f32], &[f32], f32, f32),
    pub ema: fn(&mut [f32], &[f32], f32, f32),
    pub factor_ema: fn(&mut [f32], &[f32], f32, f32),
    pub axpy: fn(&mut [f32], &[f32], f32),
    pub scale: fn(&mut [f32], f32),
    pub divide: fn(&mut [f32], f32),
    pub add_assign: fn(&mut [f32], &[f32]),
    pub alada_descent_row: fn(&mut [f32], &[f32], &[f32], f32, f32, f32, f32, f32, f32),
    pub adam_update:
        fn(&mut [f32], &mut [f32], &mut [f32], &[f32], f32, f32, f32, f32, f32, f32),
    pub sq_eps_rowcol: fn(&[f32], &mut [f32], f32) -> f32,
    pub factored_descent_row: fn(&mut [f32], &[f32], &[f32], f32, f32, f32, f32, f32),
    pub came_instability_row: fn(&[f32], &[f32], &[f32], f32, f32, f32, f32, &mut [f32]) -> f32,
    pub came_descent_row: fn(&mut [f32], &[f32], &[f32], f32, f32, f32, f32),
}

/// The oracle table: every pointer is the scalar implementation.
pub const SCALAR: Kernels = Kernels {
    backend: Backend::Scalar,
    all_finite: scalar::all_finite,
    sum: scalar::sum,
    dot: scalar::dot,
    sq_dot_scaled: scalar::sq_dot_scaled,
    sq_axpy_scaled: scalar::sq_axpy_scaled,
    ema: scalar::ema,
    factor_ema: scalar::factor_ema,
    axpy: scalar::axpy,
    scale: scalar::scale,
    divide: scalar::divide,
    add_assign: scalar::add_assign,
    alada_descent_row: scalar::alada_descent_row,
    adam_update: scalar::adam_update,
    sq_eps_rowcol: scalar::sq_eps_rowcol,
    factored_descent_row: scalar::factored_descent_row,
    came_instability_row: scalar::came_instability_row,
    came_descent_row: scalar::came_descent_row,
};

/// The table for `backend`, or `None` when the host CPU (or the build
/// target) does not support it. `Scalar` always succeeds.
pub fn table_for(backend: Backend) -> Option<Kernels> {
    match backend {
        Backend::Scalar => Some(SCALAR),
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 if is_x86_feature_detected!("avx2") => Some(avx2::TABLE),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon if std::arch::is_aarch64_feature_detected!("neon") => Some(neon::TABLE),
        _ => None,
    }
}

/// The best backend the host supports (what `auto` resolves to).
fn best() -> Kernels {
    if let Some(t) = table_for(Backend::Avx2) {
        return t;
    }
    if let Some(t) = table_for(Backend::Neon) {
        return t;
    }
    SCALAR
}

/// One dispatch decision: the chosen table plus the story for banners,
/// `alada features`, and bug reports.
pub struct Selection {
    pub kernels: Kernels,
    /// What was asked for (`"auto"` when `ALADA_SIMD` was unset).
    pub requested: String,
    /// Why the request was downgraded to scalar, when it was.
    pub note: Option<String>,
}

/// Resolve a dispatch request (the pure, testable core of the
/// `ALADA_SIMD` override): `auto`/`None` picks the best detected
/// backend, `scalar` forces the oracle, an unavailable ISA or an
/// unknown value falls back to scalar with an explanatory note —
/// never an error, never a silently wrong table.
pub fn select_with(request: Option<&str>) -> Selection {
    let requested = request.unwrap_or("auto").to_string();
    let (kernels, note) = match requested.as_str() {
        "auto" => (best(), None),
        "scalar" => (SCALAR, None),
        "avx2" => match table_for(Backend::Avx2) {
            Some(t) => (t, None),
            None => (
                SCALAR,
                Some("avx2 requested but not available on this host; using scalar".to_string()),
            ),
        },
        "neon" => match table_for(Backend::Neon) {
            Some(t) => (t, None),
            None => (
                SCALAR,
                Some("neon requested but not available on this host; using scalar".to_string()),
            ),
        },
        other => (
            SCALAR,
            Some(format!(
                "unknown ALADA_SIMD value {other:?} (known: auto, scalar, avx2, neon); \
                 using scalar"
            )),
        ),
    };
    Selection { kernels, requested, note }
}

static ACTIVE: OnceLock<Selection> = OnceLock::new();

/// The process-wide dispatch decision, made once on first use from the
/// `ALADA_SIMD` environment variable.
pub fn selection() -> &'static Selection {
    ACTIVE.get_or_init(|| select_with(std::env::var("ALADA_SIMD").ok().as_deref()))
}

/// The active backend (forces the dispatch decision if still pending).
pub fn backend() -> Backend {
    selection().kernels.backend
}

/// Detected CPU SIMD features relevant to the dispatcher, as
/// `(name, detected)` pairs — the `alada features` report body.
#[cfg(target_arch = "x86_64")]
pub fn cpu_features() -> Vec<(&'static str, bool)> {
    vec![
        ("sse2", true), // x86_64 baseline
        ("sse4.2", is_x86_feature_detected!("sse4.2")),
        ("avx", is_x86_feature_detected!("avx")),
        ("avx2", is_x86_feature_detected!("avx2")),
        ("fma", is_x86_feature_detected!("fma")), // detected, deliberately unused: FMA breaks bit-identity
    ]
}

/// Detected CPU SIMD features relevant to the dispatcher.
#[cfg(target_arch = "aarch64")]
pub fn cpu_features() -> Vec<(&'static str, bool)> {
    vec![("neon", std::arch::is_aarch64_feature_detected!("neon"))]
}

/// Detected CPU SIMD features relevant to the dispatcher (none on
/// architectures without an intrinsic backend — scalar only).
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn cpu_features() -> Vec<(&'static str, bool)> {
    Vec::new()
}

#[inline]
fn active() -> &'static Kernels {
    &selection().kernels
}

// ------------------------------------------------------------------
// Public kernel API — thin shims through the dispatch table. Call
// sites are unchanged from the pre-dispatch module; per-kernel
// contracts (expression order, association order) are documented on
// the scalar oracle in `scalar.rs`.
// ------------------------------------------------------------------

/// Fused finite scan: true iff every element is finite (no NaN/±Inf).
/// The shard engine's per-step numerical sentinel.
#[inline]
pub fn all_finite(x: &[f32]) -> bool {
    (active().all_finite)(x)
}

/// Plain sum with LANES independent accumulators. This is the one
/// blessed f32 reduction for optimizer code — lint rule r2 forbids ad
/// hoc `.sum::<f32>()` outside this module so every mean/norm shares a
/// single, fixed association order.
#[inline]
pub fn sum(x: &[f32]) -> f32 {
    (active().sum)(x)
}

/// Dot product with LANES independent accumulators.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (active().dot)(a, b)
}

/// Σ_j (m_j·s)²·q_j — Alada's even-phase row projection.
#[inline]
pub fn sq_dot_scaled(m: &[f32], q: &[f32], s: f32) -> f32 {
    (active().sq_dot_scaled)(m, q, s)
}

/// acc_j += (m_j·s)²·w — Alada's odd-phase column reduction, one row's
/// contribution.
#[inline]
pub fn sq_axpy_scaled(acc: &mut [f32], m: &[f32], s: f32, w: f32) {
    (active().sq_axpy_scaled)(acc, m, s, w)
}

/// dst = a·dst + b·src — the EMA workhorse (`Tensor::ema_inplace`).
#[inline]
pub fn ema(dst: &mut [f32], src: &[f32], a: f32, b: f32) {
    (active().ema)(dst, src, a, b)
}

/// dst = β·dst + (1−β)·src/denom — the factored-moment EMA of
/// Adafactor/CAME/Alada.
#[inline]
pub fn factor_ema(dst: &mut [f32], src: &[f32], beta: f32, denom: f32) {
    (active().factor_ema)(dst, src, beta, denom)
}

/// y += a·x.
#[inline]
pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    (active().axpy)(y, x, a)
}

/// x *= s.
#[inline]
pub fn scale(x: &mut [f32], s: f32) {
    (active().scale)(x, s)
}

/// Elementwise correctly-rounded divide (NOT multiply-by-reciprocal):
/// `x[i] /= d` — see `scalar::divide` for why the elastic-checkpoint
/// parity contract needs a true divide.
#[inline]
pub fn divide(x: &mut [f32], d: f32) {
    (active().divide)(x, d)
}

/// x += y elementwise — the collective's segment-sum building block
/// (`Comm::reduce_bucket` accumulates received partial sums with it).
#[inline]
pub fn add_assign(x: &mut [f32], y: &[f32]) {
    (active().add_assign)(x, y)
}

/// Alada descent over one row (both phases) — fused û/m̂/update pass.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn alada_descent_row(
    x: &mut [f32],
    m: &[f32],
    q: &[f32],
    pi: f32,
    bc1: f32,
    sub: f32,
    bc2_inv: f32,
    eps: f32,
    lr: f32,
) {
    (active().alada_descent_row)(x, m, q, pi, bc1, sub, bc2_inv, eps, lr)
}

/// Fused Adam element update: EMA both moments and descend in one pass.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn adam_update(
    x: &mut [f32],
    m: &mut [f32],
    u: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
    eps: f32,
) {
    (active().adam_update)(x, m, u, g, b1, b2, bc1, bc2, lr, eps)
}

/// Row/column accumulation of V = g² + ε (Adafactor/CAME first pass):
/// csum_j += v_j, returns Σ_j v_j via LANES accumulators.
#[inline]
pub fn sq_eps_rowcol(row: &[f32], csum: &mut [f32], eps: f32) -> f32 {
    (active().sq_eps_rowcol)(row, csum, eps)
}

/// Adafactor descent over one row.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn factored_descent_row(
    x: &mut [f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    lr: f32,
    eps: f32,
) {
    (active().factored_descent_row)(x, g, c, ri, bc, inv_mean, lr, eps)
}

/// CAME instability pass over one row; accumulates into `inst_c` and
/// returns the row total.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn came_instability_row(
    m: &[f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    eps: f32,
    inst_c: &mut [f32],
) -> f32 {
    (active().came_instability_row)(m, g, c, ri, bc, inv_mean, eps, inst_c)
}

/// CAME confidence-scaled descent over one row.
#[inline]
pub fn came_descent_row(
    x: &mut [f32],
    m: &[f32],
    uc: &[f32],
    uri: f32,
    inv: f32,
    lr: f32,
    eps: f32,
) {
    (active().came_descent_row)(x, m, uc, uri, inv, lr, eps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_table_is_always_available_and_scalar() {
        let t = table_for(Backend::Scalar).expect("scalar table");
        assert_eq!(t.backend, Backend::Scalar);
        assert_eq!(Backend::Scalar.name(), "scalar");
        assert_eq!(Backend::Avx2.name(), "avx2");
        assert_eq!(Backend::Neon.name(), "neon");
    }

    #[test]
    fn auto_never_downgrades_silently() {
        let sel = select_with(None);
        assert_eq!(sel.requested, "auto");
        assert!(sel.note.is_none(), "auto is never a fallback");
        // auto == the best detected backend, scalar only when nothing
        // SIMD-capable was found
        let has_simd =
            table_for(Backend::Avx2).is_some() || table_for(Backend::Neon).is_some();
        assert_eq!(sel.kernels.backend != Backend::Scalar, has_simd);
    }

    #[test]
    fn unknown_request_falls_back_to_scalar_with_a_note() {
        let sel = select_with(Some("avx512"));
        assert_eq!(sel.kernels.backend, Backend::Scalar);
        let note = sel.note.expect("downgrade must carry a note");
        assert!(note.contains("avx512") && note.contains("scalar"), "{note}");
    }

    #[test]
    fn dispatched_api_agrees_with_the_oracle_on_a_smoke_vector() {
        // Whatever backend the environment picked, the public shims
        // must return the oracle's bits (the full adversarial sweep
        // lives in rust/tests/simd_parity.rs).
        let x: Vec<f32> = (0..37).map(|i| (i as f32 - 11.0) * 0.37).collect();
        assert_eq!(sum(&x).to_bits(), (SCALAR.sum)(&x).to_bits());
        assert_eq!(dot(&x, &x).to_bits(), (SCALAR.dot)(&x, &x).to_bits());
        assert!(all_finite(&x));
    }

    #[test]
    fn cpu_feature_report_names_the_backend_isa() {
        let feats = cpu_features();
        // on x86_64/aarch64 the probed ISA list is non-empty and every
        // backend this host can install shows up as detected
        if let Some(t) = table_for(Backend::Avx2) {
            assert_eq!(t.backend, Backend::Avx2);
            assert!(feats.iter().any(|&(n, on)| n == "avx2" && on));
        }
        if let Some(t) = table_for(Backend::Neon) {
            assert_eq!(t.backend, Backend::Neon);
            assert!(feats.iter().any(|&(n, on)| n == "neon" && on));
        }
    }
}
