//! The NEON backend (`aarch64`): two `float32x4_t` registers per 8-lane
//! accumulator chunk.
//!
//! The bit-identity rules are the AVX2 ones (see `avx2.rs`), with the
//! register mapping adjusted for 128-bit vectors:
//!
//! 1. A low register carries scalar accumulator lanes 0–3 and a high
//!    register lanes 4–7; the pair maps 1:1 onto the scalar
//!    `[f32; LANES]` array, and a vertical `vaddq_f32` per half is
//!    exactly the scalar per-lane `acc[l] += …`. The horizontal combine
//!    stores both registers to one 8-float array and folds it with the
//!    same sequential loop as the scalar path — no pairwise `vpadd`
//!    trees, which would reassociate.
//! 2. No FMA (`vfmaq_f32`/`vmlaq_f32`) — separate `vmulq_f32` +
//!    `vaddq_f32` match the scalar's two roundings.
//! 3. Tails are folded inline with the same scalar loops as
//!    `scalar.rs`. Pure elementwise kernels run 4-wide (per-element
//!    results don't depend on chunk width), reductions keep the 8-lane
//!    split exactly.
//!
//! `vdivq_f32`/`vsqrtq_f32` are correctly rounded (A64), and
//! `vmaxnmq_f32` — NOT `vmaxq_f32`, whose NaN behaviour differs — is
//! the IEEE maxNum that matches the scalar `f32::max` where it can
//! matter (the ±0.0 tie is absorbed by the `+ eps` downstream).
//!
//! This module is an audited `unsafe` surface like `avx2.rs`: one scoped
//! allow, SAFETY comments audited by lint rule r8, installed by
//! [`super::table_for`] only after NEON detection.
#![allow(unsafe_code)]

use std::arch::aarch64::{
    float32x4_t, vaddq_f32, vdivq_f32, vdupq_n_f32, vld1q_f32, vmaxnmq_f32, vmulq_f32,
    vsqrtq_f32, vst1q_f32, vsubq_f32,
};

use super::{check_f32_aligned, check_same_len, Backend, Kernels, LANES};

/// 128-bit vector width in f32 lanes (half an accumulator chunk).
const Q: usize = 4;

/// The dispatch table [`super::table_for`] installs when NEON is
/// detected at runtime.
pub const TABLE: Kernels = Kernels {
    backend: Backend::Neon,
    all_finite,
    sum,
    dot,
    sq_dot_scaled,
    sq_axpy_scaled,
    ema,
    factor_ema,
    axpy,
    scale,
    divide,
    add_assign,
    alada_descent_row,
    adam_update,
    sq_eps_rowcol,
    factored_descent_row,
    came_instability_row,
    came_descent_row,
};

// SAFETY: callers guarantee NEON (table install is feature-gated); the
// two stores exactly tile the local 8-float array.
#[target_feature(enable = "neon")]
unsafe fn lanes_of(lo: float32x4_t, hi: float32x4_t) -> [f32; LANES] {
    let mut out = [0.0f32; LANES];
    // SAFETY: `out[..4]` and `out[4..]` are each one 128-bit store wide.
    unsafe {
        vst1q_f32(out.as_mut_ptr(), lo);
        vst1q_f32(out[Q..].as_mut_ptr(), hi);
    }
    out
}

pub fn all_finite(x: &[f32]) -> bool {
    check_f32_aligned!(x);
    // SAFETY: this table is only installed after NEON was detected at
    // runtime (see `table_for` in mod.rs).
    unsafe { all_finite_inner(x) }
}

// SAFETY: caller verified NEON; every load stays inside `x`'s chunks.
#[target_feature(enable = "neon")]
unsafe fn all_finite_inner(x: &[f32]) -> bool {
    // SAFETY: each 8-float chunk is tiled by two 128-bit loads.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let zero = vdupq_n_f32(0.0);
        let mut lo = zero;
        let mut hi = zero;
        for c in x[..split].chunks_exact(LANES) {
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(c.as_ptr()), zero));
            hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(c[Q..].as_ptr()), zero));
        }
        let lanes = lanes_of(lo, hi);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for &v in &x[split..] {
            s += v * 0.0;
        }
        s == 0.0
    }
}

pub fn sum(x: &[f32]) -> f32 {
    check_f32_aligned!(x);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { sum_inner(x) }
}

// SAFETY: caller verified NEON; loads stay inside `x`'s chunks.
#[target_feature(enable = "neon")]
unsafe fn sum_inner(x: &[f32]) -> f32 {
    // SAFETY: each 8-float chunk is tiled by two 128-bit loads.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for c in x[..split].chunks_exact(LANES) {
            lo = vaddq_f32(lo, vld1q_f32(c.as_ptr()));
            hi = vaddq_f32(hi, vld1q_f32(c[Q..].as_ptr()));
        }
        let lanes = lanes_of(lo, hi);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for &v in &x[split..] {
            s += v;
        }
        s
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    check_same_len!(a, b);
    check_f32_aligned!(a, b);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { dot_inner(a, b) }
}

// SAFETY: caller verified NEON; zipped chunks keep both loads in-bounds.
#[target_feature(enable = "neon")]
unsafe fn dot_inner(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: each zipped 8-float chunk is tiled by two 128-bit loads.
    unsafe {
        let split = a.len() - a.len() % LANES;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for (xa, xb) in a[..split].chunks_exact(LANES).zip(b[..split].chunks_exact(LANES)) {
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(xa.as_ptr()), vld1q_f32(xb.as_ptr())));
            hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(xa[Q..].as_ptr()), vld1q_f32(xb[Q..].as_ptr())));
        }
        let lanes = lanes_of(lo, hi);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for (x, y) in a[split..].iter().zip(&b[split..]) {
            s += x * y;
        }
        s
    }
}

pub fn sq_dot_scaled(m: &[f32], q: &[f32], s: f32) -> f32 {
    check_same_len!(m, q);
    check_f32_aligned!(m, q);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { sq_dot_scaled_inner(m, q, s) }
}

// SAFETY: caller verified NEON; zipped chunks keep both loads in-bounds.
#[target_feature(enable = "neon")]
unsafe fn sq_dot_scaled_inner(m: &[f32], q: &[f32], s: f32) -> f32 {
    // SAFETY: each zipped 8-float chunk is tiled by two 128-bit loads.
    unsafe {
        let split = m.len() - m.len() % LANES;
        let sv = vdupq_n_f32(s);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for (xm, xq) in m[..split].chunks_exact(LANES).zip(q[..split].chunks_exact(LANES)) {
            // v*v*q associates as (v*v)*q, matching the scalar loop
            let vl = vmulq_f32(vld1q_f32(xm.as_ptr()), sv);
            let vh = vmulq_f32(vld1q_f32(xm[Q..].as_ptr()), sv);
            lo = vaddq_f32(lo, vmulq_f32(vmulq_f32(vl, vl), vld1q_f32(xq.as_ptr())));
            hi = vaddq_f32(hi, vmulq_f32(vmulq_f32(vh, vh), vld1q_f32(xq[Q..].as_ptr())));
        }
        let lanes = lanes_of(lo, hi);
        let mut out = 0.0f32;
        for &l in &lanes {
            out += l;
        }
        for (x, q) in m[split..].iter().zip(&q[split..]) {
            let v = x * s;
            out += v * v * q;
        }
        out
    }
}

pub fn sq_axpy_scaled(acc: &mut [f32], m: &[f32], s: f32, w: f32) {
    check_same_len!(acc, m);
    check_f32_aligned!(acc, m);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { sq_axpy_scaled_inner(acc, m, s, w) }
}

// SAFETY: caller verified NEON; zipped 4-float chunk windows bound
// every load and store.
#[target_feature(enable = "neon")]
unsafe fn sq_axpy_scaled_inner(acc: &mut [f32], m: &[f32], s: f32, w: f32) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = acc.len() - acc.len() % Q;
        let sv = vdupq_n_f32(s);
        let wv = vdupq_n_f32(w);
        let (ah, mh) = (&mut acc[..split], &m[..split]);
        for (ac, mc) in ah.chunks_exact_mut(Q).zip(mh.chunks_exact(Q)) {
            let v = vmulq_f32(vld1q_f32(mc.as_ptr()), sv);
            let add = vmulq_f32(vmulq_f32(v, v), wv);
            vst1q_f32(ac.as_mut_ptr(), vaddq_f32(vld1q_f32(ac.as_ptr()), add));
        }
        for (a, &x) in acc[split..].iter_mut().zip(&m[split..]) {
            let v = x * s;
            *a += v * v * w;
        }
    }
}

pub fn ema(dst: &mut [f32], src: &[f32], a: f32, b: f32) {
    check_same_len!(dst, src);
    check_f32_aligned!(dst, src);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { ema_inner(dst, src, a, b) }
}

// SAFETY: caller verified NEON; zipped 4-float chunk windows bound
// every load and store.
#[target_feature(enable = "neon")]
unsafe fn ema_inner(dst: &mut [f32], src: &[f32], a: f32, b: f32) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = dst.len() - dst.len() % Q;
        let av = vdupq_n_f32(a);
        let bv = vdupq_n_f32(b);
        let (dh, sh) = (&mut dst[..split], &src[..split]);
        for (dc, sc) in dh.chunks_exact_mut(Q).zip(sh.chunks_exact(Q)) {
            let d = vmulq_f32(av, vld1q_f32(dc.as_ptr()));
            let s = vmulq_f32(bv, vld1q_f32(sc.as_ptr()));
            vst1q_f32(dc.as_mut_ptr(), vaddq_f32(d, s));
        }
        for (d, &s) in dst[split..].iter_mut().zip(&src[split..]) {
            *d = a * *d + b * s;
        }
    }
}

pub fn factor_ema(dst: &mut [f32], src: &[f32], beta: f32, denom: f32) {
    check_same_len!(dst, src);
    check_f32_aligned!(dst, src);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { factor_ema_inner(dst, src, beta, denom) }
}

// SAFETY: caller verified NEON; zipped 4-float chunk windows bound
// every load and store.
#[target_feature(enable = "neon")]
unsafe fn factor_ema_inner(dst: &mut [f32], src: &[f32], beta: f32, denom: f32) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = dst.len() - dst.len() % Q;
        let bv = vdupq_n_f32(beta);
        // (1-β) computed once in scalar f32, like the hoisted scalar form
        let ov = vdupq_n_f32(1.0 - beta);
        let dv = vdupq_n_f32(denom);
        let (dh, sh) = (&mut dst[..split], &src[..split]);
        for (dc, sc) in dh.chunks_exact_mut(Q).zip(sh.chunks_exact(Q)) {
            // β·d + ((1−β)·s)/denom — the scalar parse order exactly
            let keep = vmulq_f32(bv, vld1q_f32(dc.as_ptr()));
            let mix = vdivq_f32(vmulq_f32(ov, vld1q_f32(sc.as_ptr())), dv);
            vst1q_f32(dc.as_mut_ptr(), vaddq_f32(keep, mix));
        }
        for (d, &s) in dst[split..].iter_mut().zip(&src[split..]) {
            *d = beta * *d + (1.0 - beta) * s / denom;
        }
    }
}

pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    check_same_len!(y, x);
    check_f32_aligned!(y, x);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { axpy_inner(y, x, a) }
}

// SAFETY: caller verified NEON; zipped 4-float chunk windows bound
// every load and store.
#[target_feature(enable = "neon")]
unsafe fn axpy_inner(y: &mut [f32], x: &[f32], a: f32) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = y.len() - y.len() % Q;
        let av = vdupq_n_f32(a);
        let (yh, xh) = (&mut y[..split], &x[..split]);
        for (yc, xc) in yh.chunks_exact_mut(Q).zip(xh.chunks_exact(Q)) {
            let add = vmulq_f32(av, vld1q_f32(xc.as_ptr()));
            vst1q_f32(yc.as_mut_ptr(), vaddq_f32(vld1q_f32(yc.as_ptr()), add));
        }
        for (yi, &xi) in y[split..].iter_mut().zip(&x[split..]) {
            *yi += a * xi;
        }
    }
}

pub fn scale(x: &mut [f32], s: f32) {
    check_f32_aligned!(x);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { scale_inner(x, s) }
}

// SAFETY: caller verified NEON; 4-float chunk windows bound every access.
#[target_feature(enable = "neon")]
unsafe fn scale_inner(x: &mut [f32], s: f32) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = x.len() - x.len() % Q;
        let sv = vdupq_n_f32(s);
        for c in x[..split].chunks_exact_mut(Q) {
            vst1q_f32(c.as_mut_ptr(), vmulq_f32(vld1q_f32(c.as_ptr()), sv));
        }
        for v in &mut x[split..] {
            *v *= s;
        }
    }
}

pub fn divide(x: &mut [f32], d: f32) {
    check_f32_aligned!(x);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { divide_inner(x, d) }
}

// `vdivq_f32` is a true correctly-rounded divide, preserving the
// scalar kernel's no-reciprocal contract (see scalar::divide).
// SAFETY: caller verified NEON; 4-float chunks bound every access.
#[target_feature(enable = "neon")]
unsafe fn divide_inner(x: &mut [f32], d: f32) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = x.len() - x.len() % Q;
        let dv = vdupq_n_f32(d);
        for c in x[..split].chunks_exact_mut(Q) {
            vst1q_f32(c.as_mut_ptr(), vdivq_f32(vld1q_f32(c.as_ptr()), dv));
        }
        for v in &mut x[split..] {
            *v /= d;
        }
    }
}

pub fn add_assign(x: &mut [f32], y: &[f32]) {
    check_same_len!(x, y);
    check_f32_aligned!(x, y);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { add_assign_inner(x, y) }
}

// SAFETY: caller verified NEON; zipped 4-float chunk windows bound
// every load and store.
#[target_feature(enable = "neon")]
unsafe fn add_assign_inner(x: &mut [f32], y: &[f32]) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = x.len() - x.len() % Q;
        let (xh, yh) = (&mut x[..split], &y[..split]);
        for (xc, yc) in xh.chunks_exact_mut(Q).zip(yh.chunks_exact(Q)) {
            vst1q_f32(
                xc.as_mut_ptr(),
                vaddq_f32(vld1q_f32(xc.as_ptr()), vld1q_f32(yc.as_ptr())),
            );
        }
        for (a, &b) in x[split..].iter_mut().zip(&y[split..]) {
            *a += b;
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn alada_descent_row(
    x: &mut [f32],
    m: &[f32],
    q: &[f32],
    pi: f32,
    bc1: f32,
    sub: f32,
    bc2_inv: f32,
    eps: f32,
    lr: f32,
) {
    check_same_len!(x, m, q);
    check_f32_aligned!(x, m, q);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { alada_descent_row_inner(x, m, q, pi, bc1, sub, bc2_inv, eps, lr) }
}

// `vmaxnmq_f32` is IEEE maxNum, matching the scalar `f32::max(u, 0.0)`
// (±0.0 tie signs are erased by the `+ eps`, eps > 0).
// SAFETY: caller verified NEON; zipped 4-float chunks bound every access.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn alada_descent_row_inner(
    x: &mut [f32],
    m: &[f32],
    q: &[f32],
    pi: f32,
    bc1: f32,
    sub: f32,
    bc2_inv: f32,
    eps: f32,
    lr: f32,
) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = x.len() - x.len() % Q;
        let piv = vdupq_n_f32(pi);
        let bc1v = vdupq_n_f32(bc1);
        let subv = vdupq_n_f32(sub);
        let bc2v = vdupq_n_f32(bc2_inv);
        let epsv = vdupq_n_f32(eps);
        let lrv = vdupq_n_f32(lr);
        let zero = vdupq_n_f32(0.0);
        let (xh, mh, qh) = (&mut x[..split], &m[..split], &q[..split]);
        for ((xc, mc), qc) in xh
            .chunks_exact_mut(Q)
            .zip(mh.chunks_exact(Q))
            .zip(qh.chunks_exact(Q))
        {
            let u_raw = vsubq_f32(vmulq_f32(piv, vld1q_f32(qc.as_ptr())), subv);
            let u_hat = vmulq_f32(vmaxnmq_f32(u_raw, zero), bc2v);
            let m_hat = vmulq_f32(vld1q_f32(mc.as_ptr()), bc1v);
            let denom = vsqrtq_f32(vaddq_f32(u_hat, epsv));
            let step = vdivq_f32(vmulq_f32(lrv, m_hat), denom);
            vst1q_f32(xc.as_mut_ptr(), vsubq_f32(vld1q_f32(xc.as_ptr()), step));
        }
        for ((xj, &mj), &qj) in x[split..].iter_mut().zip(&m[split..]).zip(&q[split..]) {
            let u_hat = (pi * qj - sub).max(0.0) * bc2_inv;
            let m_hat = mj * bc1;
            *xj -= lr * m_hat / (u_hat + eps).sqrt();
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn adam_update(
    x: &mut [f32],
    m: &mut [f32],
    u: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, m, u, g);
    check_f32_aligned!(x, m, u, g);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { adam_update_inner(x, m, u, g, b1, b2, bc1, bc2, lr, eps) }
}

// SAFETY: caller verified NEON; four zipped chunks bound every access.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn adam_update_inner(
    x: &mut [f32],
    m: &mut [f32],
    u: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
    eps: f32,
) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = x.len() - x.len() % Q;
        let b1v = vdupq_n_f32(b1);
        let b2v = vdupq_n_f32(b2);
        // (1-β) in scalar f32 first, exactly like the scalar expression
        let omb1v = vdupq_n_f32(1.0 - b1);
        let omb2v = vdupq_n_f32(1.0 - b2);
        let bc1v = vdupq_n_f32(bc1);
        let bc2v = vdupq_n_f32(bc2);
        let lrv = vdupq_n_f32(lr);
        let epsv = vdupq_n_f32(eps);
        let (xh, mh, uh, gh) = (&mut x[..split], &mut m[..split], &mut u[..split], &g[..split]);
        for (((xc, mc), uc), gc) in xh
            .chunks_exact_mut(Q)
            .zip(mh.chunks_exact_mut(Q))
            .zip(uh.chunks_exact_mut(Q))
            .zip(gh.chunks_exact(Q))
        {
            let gv = vld1q_f32(gc.as_ptr());
            // m = b1·m + (1−b1)·g ; u = b2·u + ((1−b2)·g)·g — scalar order
            let mv = vaddq_f32(vmulq_f32(b1v, vld1q_f32(mc.as_ptr())), vmulq_f32(omb1v, gv));
            let uv = vaddq_f32(
                vmulq_f32(b2v, vld1q_f32(uc.as_ptr())),
                vmulq_f32(vmulq_f32(omb2v, gv), gv),
            );
            vst1q_f32(mc.as_mut_ptr(), mv);
            vst1q_f32(uc.as_mut_ptr(), uv);
            let m_hat = vmulq_f32(mv, bc1v);
            let u_hat = vmulq_f32(uv, bc2v);
            let denom = vaddq_f32(vsqrtq_f32(u_hat), epsv);
            let step = vdivq_f32(vmulq_f32(lrv, m_hat), denom);
            vst1q_f32(xc.as_mut_ptr(), vsubq_f32(vld1q_f32(xc.as_ptr()), step));
        }
        for (((xj, mj), uj), &gj) in x[split..]
            .iter_mut()
            .zip(m[split..].iter_mut())
            .zip(u[split..].iter_mut())
            .zip(&g[split..])
        {
            *mj = b1 * *mj + (1.0 - b1) * gj;
            *uj = b2 * *uj + (1.0 - b2) * gj * gj;
            let m_hat = *mj * bc1;
            let u_hat = *uj * bc2;
            *xj -= lr * m_hat / (u_hat.sqrt() + eps);
        }
    }
}

pub fn sq_eps_rowcol(row: &[f32], csum: &mut [f32], eps: f32) -> f32 {
    check_same_len!(row, csum);
    check_f32_aligned!(row, csum);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { sq_eps_rowcol_inner(row, csum, eps) }
}

// SAFETY: caller verified NEON; zipped chunk windows bound every access.
#[target_feature(enable = "neon")]
unsafe fn sq_eps_rowcol_inner(row: &[f32], csum: &mut [f32], eps: f32) -> f32 {
    // SAFETY: each 8-float chunk is tiled by two 128-bit loads/stores.
    unsafe {
        let split = row.len() - row.len() % LANES;
        let epsv = vdupq_n_f32(eps);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let (rh, ch) = (&row[..split], &mut csum[..split]);
        for (rc, cc) in rh.chunks_exact(LANES).zip(ch.chunks_exact_mut(LANES)) {
            let rl = vld1q_f32(rc.as_ptr());
            let rh2 = vld1q_f32(rc[Q..].as_ptr());
            let vl = vaddq_f32(vmulq_f32(rl, rl), epsv);
            let vh = vaddq_f32(vmulq_f32(rh2, rh2), epsv);
            vst1q_f32(cc.as_mut_ptr(), vaddq_f32(vld1q_f32(cc.as_ptr()), vl));
            vst1q_f32(cc[Q..].as_mut_ptr(), vaddq_f32(vld1q_f32(cc[Q..].as_ptr()), vh));
            lo = vaddq_f32(lo, vl);
            hi = vaddq_f32(hi, vh);
        }
        let lanes = lanes_of(lo, hi);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for (&x, c) in row[split..].iter().zip(&mut csum[split..]) {
            let v = x * x + eps;
            *c += v;
            s += v;
        }
        s
    }
}

#[allow(clippy::too_many_arguments)]
pub fn factored_descent_row(
    x: &mut [f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, g, c);
    check_f32_aligned!(x, g, c);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { factored_descent_row_inner(x, g, c, ri, bc, inv_mean, lr, eps) }
}

// SAFETY: caller verified NEON; zipped 4-float chunks bound every access.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn factored_descent_row_inner(
    x: &mut [f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    lr: f32,
    eps: f32,
) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = x.len() - x.len() % Q;
        let riv = vdupq_n_f32(ri);
        let bcv = vdupq_n_f32(bc);
        let imv = vdupq_n_f32(inv_mean);
        let lrv = vdupq_n_f32(lr);
        let epsv = vdupq_n_f32(eps);
        let (xh, gh, ch) = (&mut x[..split], &g[..split], &c[..split]);
        for ((xc, gc), cc) in xh
            .chunks_exact_mut(Q)
            .zip(gh.chunks_exact(Q))
            .zip(ch.chunks_exact(Q))
        {
            // (ri·(c·bc))·inv_mean — the scalar parse order exactly
            let u = vmulq_f32(vmulq_f32(riv, vmulq_f32(vld1q_f32(cc.as_ptr()), bcv)), imv);
            let denom = vaddq_f32(vsqrtq_f32(u), epsv);
            let step = vdivq_f32(vmulq_f32(lrv, vld1q_f32(gc.as_ptr())), denom);
            vst1q_f32(xc.as_mut_ptr(), vsubq_f32(vld1q_f32(xc.as_ptr()), step));
        }
        for ((xj, &gj), &cj) in x[split..].iter_mut().zip(&g[split..]).zip(&c[split..]) {
            let u = ri * (cj * bc) * inv_mean;
            *xj -= lr * gj / (u.sqrt() + eps);
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn came_instability_row(
    m: &[f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    eps: f32,
    inst_c: &mut [f32],
) -> f32 {
    check_same_len!(m, g, c, inst_c);
    check_f32_aligned!(m, g, c, inst_c);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { came_instability_row_inner(m, g, c, ri, bc, inv_mean, eps, inst_c) }
}

// SAFETY: caller verified NEON; four zipped chunks bound every access.
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn came_instability_row_inner(
    m: &[f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    eps: f32,
    inst_c: &mut [f32],
) -> f32 {
    // SAFETY: each 8-float chunk is tiled by two 128-bit loads/stores.
    unsafe {
        let split = m.len() - m.len() % LANES;
        let riv = vdupq_n_f32(ri);
        let bcv = vdupq_n_f32(bc);
        let imv = vdupq_n_f32(inv_mean);
        let epsv = vdupq_n_f32(eps);
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let (mh, gh, ch, ih) = (&m[..split], &g[..split], &c[..split], &mut inst_c[..split]);
        for (((mc, gc), cc), ic) in mh
            .chunks_exact(LANES)
            .zip(gh.chunks_exact(LANES))
            .zip(ch.chunks_exact(LANES))
            .zip(ih.chunks_exact_mut(LANES))
        {
            let ul = vmulq_f32(vmulq_f32(riv, vmulq_f32(vld1q_f32(cc.as_ptr()), bcv)), imv);
            let uh = vmulq_f32(vmulq_f32(riv, vmulq_f32(vld1q_f32(cc[Q..].as_ptr()), bcv)), imv);
            let uhl = vdivq_f32(vld1q_f32(gc.as_ptr()), vaddq_f32(vsqrtq_f32(ul), epsv));
            let uhh = vdivq_f32(vld1q_f32(gc[Q..].as_ptr()), vaddq_f32(vsqrtq_f32(uh), epsv));
            let dl = vsubq_f32(vld1q_f32(mc.as_ptr()), uhl);
            let dh = vsubq_f32(vld1q_f32(mc[Q..].as_ptr()), uhh);
            let vl = vaddq_f32(vmulq_f32(dl, dl), epsv);
            let vh = vaddq_f32(vmulq_f32(dh, dh), epsv);
            vst1q_f32(ic.as_mut_ptr(), vaddq_f32(vld1q_f32(ic.as_ptr()), vl));
            vst1q_f32(ic[Q..].as_mut_ptr(), vaddq_f32(vld1q_f32(ic[Q..].as_ptr()), vh));
            lo = vaddq_f32(lo, vl);
            hi = vaddq_f32(hi, vh);
        }
        let lanes = lanes_of(lo, hi);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for i in split..m.len() {
            let u = ri * (c[i] * bc) * inv_mean;
            let u_hat = g[i] / (u.sqrt() + eps);
            let d = m[i] - u_hat;
            let v = d * d + eps;
            inst_c[i] += v;
            s += v;
        }
        s
    }
}

pub fn came_descent_row(
    x: &mut [f32],
    m: &[f32],
    uc: &[f32],
    uri: f32,
    inv: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, m, uc);
    check_f32_aligned!(x, m, uc);
    // SAFETY: table install is gated on NEON detection (mod.rs).
    unsafe { came_descent_row_inner(x, m, uc, uri, inv, lr, eps) }
}

// SAFETY: caller verified NEON; zipped 4-float chunk windows bound
// every load and store.
#[target_feature(enable = "neon")]
unsafe fn came_descent_row_inner(
    x: &mut [f32],
    m: &[f32],
    uc: &[f32],
    uri: f32,
    inv: f32,
    lr: f32,
    eps: f32,
) {
    // SAFETY: 4-float chunks match the 128-bit load/store width.
    unsafe {
        let split = x.len() - x.len() % Q;
        let uriv = vdupq_n_f32(uri);
        let invv = vdupq_n_f32(inv);
        let lrv = vdupq_n_f32(lr);
        let epsv = vdupq_n_f32(eps);
        let (xh, mh, uh) = (&mut x[..split], &m[..split], &uc[..split]);
        for ((xc, mc), ucc) in xh
            .chunks_exact_mut(Q)
            .zip(mh.chunks_exact(Q))
            .zip(uh.chunks_exact(Q))
        {
            // ((uri·uc)·inv) then √ then +eps — the scalar parse order
            let prod = vmulq_f32(vmulq_f32(uriv, vld1q_f32(ucc.as_ptr())), invv);
            let denom = vaddq_f32(vsqrtq_f32(prod), epsv);
            let step = vdivq_f32(vmulq_f32(lrv, vld1q_f32(mc.as_ptr())), denom);
            vst1q_f32(xc.as_mut_ptr(), vsubq_f32(vld1q_f32(xc.as_ptr()), step));
        }
        for ((xj, &mj), &ucj) in x[split..].iter_mut().zip(&m[split..]).zip(&uc[split..]) {
            let s = (uri * ucj * inv).sqrt() + eps;
            *xj -= lr * mj / s;
        }
    }
}
