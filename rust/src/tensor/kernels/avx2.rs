//! The AVX2 backend (`x86_64`): one `__m256` register per 8-lane
//! accumulator chunk.
//!
//! Bit-identity with [`super::scalar`] falls out of three rules, applied
//! to every kernel here:
//!
//! 1. One `__m256` maps 1:1 onto the scalar `[f32; LANES]` accumulator
//!    array; a vertical `_mm256_add_ps` per chunk is exactly the scalar
//!    per-lane `acc[l] += …`. The horizontal combine stores the register
//!    to an array and folds it with the same sequential loop the scalar
//!    path runs — never a shuffle/`hadd` tree, which would reassociate.
//! 2. No FMA, ever. `_mm256_fmadd_ps` rounds once where the scalar
//!    `a*b + c` rounds twice; separate `_mm256_mul_ps` + `_mm256_add_ps`
//!    match the scalar rounding exactly. (The dispatcher reports the
//!    `fma` CPU flag but no backend uses it — by design.)
//! 3. Tails (`len % LANES` trailing elements) are folded inline with the
//!    *same* scalar loops as `scalar.rs` — not delegated to the scalar
//!    kernels, whose own lane split would reassociate the tail.
//!
//! All remaining intrinsics (`_mm256_div_ps`, `_mm256_sqrt_ps`) are
//! correctly rounded per IEEE 754, and `_mm256_max_ps(v, 0.0)` agrees
//! with the scalar `f32::max(v, 0.0)` everywhere it can matter: NaN in
//! either lane yields the second operand (0.0) in both forms, and the
//! ±0.0 tie — where the two forms may disagree on sign — is absorbed by
//! the `+ eps`/`* bc2_inv` that immediately follows (eps > 0).
//!
//! This module is one of the two audited `unsafe` surfaces in the tree
//! (the other is the signal-FFI site in main.rs): the crate is
//! `#![deny(unsafe_code)]` and each backend carries exactly one scoped
//! allow, with lint rule r8 enforcing a SAFETY comment on every unsafe
//! line. The safety argument is uniform — intrinsics here are plain
//! arithmetic on in-bounds slice chunks, unsafe only because the ISA
//! must exist, and [`super::table_for`] installs this table exclusively
//! after `is_x86_feature_detected!("avx2")` returns true.
#![allow(unsafe_code)]

use std::arch::x86_64::{
    __m256, _mm256_add_ps, _mm256_div_ps, _mm256_loadu_ps, _mm256_max_ps, _mm256_mul_ps,
    _mm256_set1_ps, _mm256_setzero_ps, _mm256_sqrt_ps, _mm256_storeu_ps, _mm256_sub_ps,
};

use super::{check_f32_aligned, check_same_len, Backend, Kernels, LANES};

/// The dispatch table [`super::table_for`] installs when AVX2 is
/// detected at runtime.
pub const TABLE: Kernels = Kernels {
    backend: Backend::Avx2,
    all_finite,
    sum,
    dot,
    sq_dot_scaled,
    sq_axpy_scaled,
    ema,
    factor_ema,
    axpy,
    scale,
    divide,
    add_assign,
    alada_descent_row,
    adam_update,
    sq_eps_rowcol,
    factored_descent_row,
    came_instability_row,
    came_descent_row,
};

// SAFETY: callers guarantee AVX2 (table install is feature-gated); the
// store target is a local 8-float array, exactly one __m256 wide.
#[target_feature(enable = "avx2")]
unsafe fn lanes_of(v: __m256) -> [f32; LANES] {
    let mut out = [0.0f32; LANES];
    // SAFETY: `out` spans 8 f32s, the exact width of one unaligned store.
    unsafe { _mm256_storeu_ps(out.as_mut_ptr(), v) };
    out
}

pub fn all_finite(x: &[f32]) -> bool {
    check_f32_aligned!(x);
    // SAFETY: this table is only installed after is_x86_feature_detected!
    // confirmed AVX2 (see `table_for` in mod.rs).
    unsafe { all_finite_inner(x) }
}

// SAFETY: caller verified AVX2; every load stays inside `x`'s chunks.
#[target_feature(enable = "avx2")]
unsafe fn all_finite_inner(x: &[f32]) -> bool {
    // SAFETY: `chunks_exact(LANES)` yields 8-float windows, matching the
    // unaligned 256-bit load width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let zero = _mm256_setzero_ps();
        let mut acc = zero;
        for c in x[..split].chunks_exact(LANES) {
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_loadu_ps(c.as_ptr()), zero));
        }
        let lanes = lanes_of(acc);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for &v in &x[split..] {
            s += v * 0.0;
        }
        s == 0.0
    }
}

pub fn sum(x: &[f32]) -> f32 {
    check_f32_aligned!(x);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { sum_inner(x) }
}

// SAFETY: caller verified AVX2; loads stay inside `x`'s chunks.
#[target_feature(enable = "avx2")]
unsafe fn sum_inner(x: &[f32]) -> f32 {
    // SAFETY: 8-float chunks match the unaligned 256-bit load width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let mut acc = _mm256_setzero_ps();
        for c in x[..split].chunks_exact(LANES) {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(c.as_ptr()));
        }
        let lanes = lanes_of(acc);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for &v in &x[split..] {
            s += v;
        }
        s
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    check_same_len!(a, b);
    check_f32_aligned!(a, b);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { dot_inner(a, b) }
}

// SAFETY: caller verified AVX2; zipped chunks keep both loads in-bounds.
#[target_feature(enable = "avx2")]
unsafe fn dot_inner(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: zipped 8-float chunks match the unaligned load width.
    unsafe {
        let split = a.len() - a.len() % LANES;
        let mut acc = _mm256_setzero_ps();
        for (xa, xb) in a[..split].chunks_exact(LANES).zip(b[..split].chunks_exact(LANES)) {
            acc = _mm256_add_ps(
                acc,
                _mm256_mul_ps(_mm256_loadu_ps(xa.as_ptr()), _mm256_loadu_ps(xb.as_ptr())),
            );
        }
        let lanes = lanes_of(acc);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for (x, y) in a[split..].iter().zip(&b[split..]) {
            s += x * y;
        }
        s
    }
}

pub fn sq_dot_scaled(m: &[f32], q: &[f32], s: f32) -> f32 {
    check_same_len!(m, q);
    check_f32_aligned!(m, q);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { sq_dot_scaled_inner(m, q, s) }
}

// SAFETY: caller verified AVX2; zipped chunks keep both loads in-bounds.
#[target_feature(enable = "avx2")]
unsafe fn sq_dot_scaled_inner(m: &[f32], q: &[f32], s: f32) -> f32 {
    // SAFETY: zipped 8-float chunks match the unaligned load width.
    unsafe {
        let split = m.len() - m.len() % LANES;
        let sv = _mm256_set1_ps(s);
        let mut acc = _mm256_setzero_ps();
        for (xm, xq) in m[..split].chunks_exact(LANES).zip(q[..split].chunks_exact(LANES)) {
            // v*v*q associates as (v*v)*q, matching the scalar loop
            let v = _mm256_mul_ps(_mm256_loadu_ps(xm.as_ptr()), sv);
            acc = _mm256_add_ps(
                acc,
                _mm256_mul_ps(_mm256_mul_ps(v, v), _mm256_loadu_ps(xq.as_ptr())),
            );
        }
        let lanes = lanes_of(acc);
        let mut out = 0.0f32;
        for &l in &lanes {
            out += l;
        }
        for (x, q) in m[split..].iter().zip(&q[split..]) {
            let v = x * s;
            out += v * v * q;
        }
        out
    }
}

pub fn sq_axpy_scaled(acc: &mut [f32], m: &[f32], s: f32, w: f32) {
    check_same_len!(acc, m);
    check_f32_aligned!(acc, m);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { sq_axpy_scaled_inner(acc, m, s, w) }
}

// SAFETY: caller verified AVX2; loads and stores stay inside the zipped
// chunk windows of the two equal-length slices.
#[target_feature(enable = "avx2")]
unsafe fn sq_axpy_scaled_inner(acc: &mut [f32], m: &[f32], s: f32, w: f32) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = acc.len() - acc.len() % LANES;
        let sv = _mm256_set1_ps(s);
        let wv = _mm256_set1_ps(w);
        let (ah, mh) = (&mut acc[..split], &m[..split]);
        for (ac, mc) in ah.chunks_exact_mut(LANES).zip(mh.chunks_exact(LANES)) {
            let v = _mm256_mul_ps(_mm256_loadu_ps(mc.as_ptr()), sv);
            let add = _mm256_mul_ps(_mm256_mul_ps(v, v), wv);
            _mm256_storeu_ps(ac.as_mut_ptr(), _mm256_add_ps(_mm256_loadu_ps(ac.as_ptr()), add));
        }
        for (a, &x) in acc[split..].iter_mut().zip(&m[split..]) {
            let v = x * s;
            *a += v * v * w;
        }
    }
}

pub fn ema(dst: &mut [f32], src: &[f32], a: f32, b: f32) {
    check_same_len!(dst, src);
    check_f32_aligned!(dst, src);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { ema_inner(dst, src, a, b) }
}

// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn ema_inner(dst: &mut [f32], src: &[f32], a: f32, b: f32) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = dst.len() - dst.len() % LANES;
        let av = _mm256_set1_ps(a);
        let bv = _mm256_set1_ps(b);
        let (dh, sh) = (&mut dst[..split], &src[..split]);
        for (dc, sc) in dh.chunks_exact_mut(LANES).zip(sh.chunks_exact(LANES)) {
            let d = _mm256_mul_ps(av, _mm256_loadu_ps(dc.as_ptr()));
            let s = _mm256_mul_ps(bv, _mm256_loadu_ps(sc.as_ptr()));
            _mm256_storeu_ps(dc.as_mut_ptr(), _mm256_add_ps(d, s));
        }
        for (d, &s) in dst[split..].iter_mut().zip(&src[split..]) {
            *d = a * *d + b * s;
        }
    }
}

pub fn factor_ema(dst: &mut [f32], src: &[f32], beta: f32, denom: f32) {
    check_same_len!(dst, src);
    check_f32_aligned!(dst, src);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { factor_ema_inner(dst, src, beta, denom) }
}

// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn factor_ema_inner(dst: &mut [f32], src: &[f32], beta: f32, denom: f32) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = dst.len() - dst.len() % LANES;
        let bv = _mm256_set1_ps(beta);
        // (1-β) computed once in scalar f32, like the hoisted scalar form
        let omb = 1.0 - beta;
        let ov = _mm256_set1_ps(omb);
        let dv = _mm256_set1_ps(denom);
        let (dh, sh) = (&mut dst[..split], &src[..split]);
        for (dc, sc) in dh.chunks_exact_mut(LANES).zip(sh.chunks_exact(LANES)) {
            // β·d + ((1−β)·s)/denom — the scalar parse order exactly
            let keep = _mm256_mul_ps(bv, _mm256_loadu_ps(dc.as_ptr()));
            let mix = _mm256_div_ps(_mm256_mul_ps(ov, _mm256_loadu_ps(sc.as_ptr())), dv);
            _mm256_storeu_ps(dc.as_mut_ptr(), _mm256_add_ps(keep, mix));
        }
        for (d, &s) in dst[split..].iter_mut().zip(&src[split..]) {
            *d = beta * *d + (1.0 - beta) * s / denom;
        }
    }
}

pub fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    check_same_len!(y, x);
    check_f32_aligned!(y, x);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { axpy_inner(y, x, a) }
}

// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn axpy_inner(y: &mut [f32], x: &[f32], a: f32) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = y.len() - y.len() % LANES;
        let av = _mm256_set1_ps(a);
        let (yh, xh) = (&mut y[..split], &x[..split]);
        for (yc, xc) in yh.chunks_exact_mut(LANES).zip(xh.chunks_exact(LANES)) {
            let add = _mm256_mul_ps(av, _mm256_loadu_ps(xc.as_ptr()));
            _mm256_storeu_ps(yc.as_mut_ptr(), _mm256_add_ps(_mm256_loadu_ps(yc.as_ptr()), add));
        }
        for (yi, &xi) in y[split..].iter_mut().zip(&x[split..]) {
            *yi += a * xi;
        }
    }
}

pub fn scale(x: &mut [f32], s: f32) {
    check_f32_aligned!(x);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { scale_inner(x, s) }
}

// SAFETY: caller verified AVX2; chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn scale_inner(x: &mut [f32], s: f32) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let sv = _mm256_set1_ps(s);
        for c in x[..split].chunks_exact_mut(LANES) {
            _mm256_storeu_ps(c.as_mut_ptr(), _mm256_mul_ps(_mm256_loadu_ps(c.as_ptr()), sv));
        }
        for v in &mut x[split..] {
            *v *= s;
        }
    }
}

pub fn divide(x: &mut [f32], d: f32) {
    check_f32_aligned!(x);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { divide_inner(x, d) }
}

// `_mm256_div_ps` is a true correctly-rounded divide, preserving the
// scalar kernel's no-reciprocal contract (see scalar::divide).
// SAFETY: caller verified AVX2; chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn divide_inner(x: &mut [f32], d: f32) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let dv = _mm256_set1_ps(d);
        for c in x[..split].chunks_exact_mut(LANES) {
            _mm256_storeu_ps(c.as_mut_ptr(), _mm256_div_ps(_mm256_loadu_ps(c.as_ptr()), dv));
        }
        for v in &mut x[split..] {
            *v /= d;
        }
    }
}

pub fn add_assign(x: &mut [f32], y: &[f32]) {
    check_same_len!(x, y);
    check_f32_aligned!(x, y);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { add_assign_inner(x, y) }
}

// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn add_assign_inner(x: &mut [f32], y: &[f32]) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let (xh, yh) = (&mut x[..split], &y[..split]);
        for (xc, yc) in xh.chunks_exact_mut(LANES).zip(yh.chunks_exact(LANES)) {
            let sum = _mm256_add_ps(_mm256_loadu_ps(xc.as_ptr()), _mm256_loadu_ps(yc.as_ptr()));
            _mm256_storeu_ps(xc.as_mut_ptr(), sum);
        }
        for (a, &b) in x[split..].iter_mut().zip(&y[split..]) {
            *a += b;
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn alada_descent_row(
    x: &mut [f32],
    m: &[f32],
    q: &[f32],
    pi: f32,
    bc1: f32,
    sub: f32,
    bc2_inv: f32,
    eps: f32,
    lr: f32,
) {
    check_same_len!(x, m, q);
    check_f32_aligned!(x, m, q);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { alada_descent_row_inner(x, m, q, pi, bc1, sub, bc2_inv, eps, lr) }
}

// `_mm256_max_ps(u, 0)` matches the scalar `f32::max(u, 0.0)`: NaN
// yields the 0.0 operand in both, and a ±0.0 sign difference on the tie
// is erased by the `+ eps` (eps > 0) before the value is consumed.
// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn alada_descent_row_inner(
    x: &mut [f32],
    m: &[f32],
    q: &[f32],
    pi: f32,
    bc1: f32,
    sub: f32,
    bc2_inv: f32,
    eps: f32,
    lr: f32,
) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let piv = _mm256_set1_ps(pi);
        let bc1v = _mm256_set1_ps(bc1);
        let subv = _mm256_set1_ps(sub);
        let bc2v = _mm256_set1_ps(bc2_inv);
        let epsv = _mm256_set1_ps(eps);
        let lrv = _mm256_set1_ps(lr);
        let zero = _mm256_setzero_ps();
        let (xh, mh, qh) = (&mut x[..split], &m[..split], &q[..split]);
        for ((xc, mc), qc) in xh
            .chunks_exact_mut(LANES)
            .zip(mh.chunks_exact(LANES))
            .zip(qh.chunks_exact(LANES))
        {
            let u_raw = _mm256_sub_ps(_mm256_mul_ps(piv, _mm256_loadu_ps(qc.as_ptr())), subv);
            let u_hat = _mm256_mul_ps(_mm256_max_ps(u_raw, zero), bc2v);
            let m_hat = _mm256_mul_ps(_mm256_loadu_ps(mc.as_ptr()), bc1v);
            let denom = _mm256_sqrt_ps(_mm256_add_ps(u_hat, epsv));
            let step = _mm256_div_ps(_mm256_mul_ps(lrv, m_hat), denom);
            _mm256_storeu_ps(xc.as_mut_ptr(), _mm256_sub_ps(_mm256_loadu_ps(xc.as_ptr()), step));
        }
        for ((xj, &mj), &qj) in x[split..].iter_mut().zip(&m[split..]).zip(&q[split..]) {
            let u_hat = (pi * qj - sub).max(0.0) * bc2_inv;
            let m_hat = mj * bc1;
            *xj -= lr * m_hat / (u_hat + eps).sqrt();
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn adam_update(
    x: &mut [f32],
    m: &mut [f32],
    u: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, m, u, g);
    check_f32_aligned!(x, m, u, g);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { adam_update_inner(x, m, u, g, b1, b2, bc1, bc2, lr, eps) }
}

// SAFETY: caller verified AVX2; four zipped chunks bound every access.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn adam_update_inner(
    x: &mut [f32],
    m: &mut [f32],
    u: &mut [f32],
    g: &[f32],
    b1: f32,
    b2: f32,
    bc1: f32,
    bc2: f32,
    lr: f32,
    eps: f32,
) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let b1v = _mm256_set1_ps(b1);
        let b2v = _mm256_set1_ps(b2);
        // (1-β) in scalar f32 first, exactly like the scalar expression
        let omb1v = _mm256_set1_ps(1.0 - b1);
        let omb2v = _mm256_set1_ps(1.0 - b2);
        let bc1v = _mm256_set1_ps(bc1);
        let bc2v = _mm256_set1_ps(bc2);
        let lrv = _mm256_set1_ps(lr);
        let epsv = _mm256_set1_ps(eps);
        let (xh, mh, uh, gh) = (&mut x[..split], &mut m[..split], &mut u[..split], &g[..split]);
        for (((xc, mc), uc), gc) in xh
            .chunks_exact_mut(LANES)
            .zip(mh.chunks_exact_mut(LANES))
            .zip(uh.chunks_exact_mut(LANES))
            .zip(gh.chunks_exact(LANES))
        {
            let gv = _mm256_loadu_ps(gc.as_ptr());
            // m = b1·m + (1−b1)·g ; u = b2·u + ((1−b2)·g)·g — scalar order
            let mv = _mm256_add_ps(
                _mm256_mul_ps(b1v, _mm256_loadu_ps(mc.as_ptr())),
                _mm256_mul_ps(omb1v, gv),
            );
            let uv = _mm256_add_ps(
                _mm256_mul_ps(b2v, _mm256_loadu_ps(uc.as_ptr())),
                _mm256_mul_ps(_mm256_mul_ps(omb2v, gv), gv),
            );
            _mm256_storeu_ps(mc.as_mut_ptr(), mv);
            _mm256_storeu_ps(uc.as_mut_ptr(), uv);
            let m_hat = _mm256_mul_ps(mv, bc1v);
            let u_hat = _mm256_mul_ps(uv, bc2v);
            let denom = _mm256_add_ps(_mm256_sqrt_ps(u_hat), epsv);
            let step = _mm256_div_ps(_mm256_mul_ps(lrv, m_hat), denom);
            _mm256_storeu_ps(xc.as_mut_ptr(), _mm256_sub_ps(_mm256_loadu_ps(xc.as_ptr()), step));
        }
        for (((xj, mj), uj), &gj) in x[split..]
            .iter_mut()
            .zip(m[split..].iter_mut())
            .zip(u[split..].iter_mut())
            .zip(&g[split..])
        {
            *mj = b1 * *mj + (1.0 - b1) * gj;
            *uj = b2 * *uj + (1.0 - b2) * gj * gj;
            let m_hat = *mj * bc1;
            let u_hat = *uj * bc2;
            *xj -= lr * m_hat / (u_hat.sqrt() + eps);
        }
    }
}

pub fn sq_eps_rowcol(row: &[f32], csum: &mut [f32], eps: f32) -> f32 {
    check_same_len!(row, csum);
    check_f32_aligned!(row, csum);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { sq_eps_rowcol_inner(row, csum, eps) }
}

// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn sq_eps_rowcol_inner(row: &[f32], csum: &mut [f32], eps: f32) -> f32 {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = row.len() - row.len() % LANES;
        let epsv = _mm256_set1_ps(eps);
        let mut acc = _mm256_setzero_ps();
        let (rh, ch) = (&row[..split], &mut csum[..split]);
        for (rc, cc) in rh.chunks_exact(LANES).zip(ch.chunks_exact_mut(LANES)) {
            let r = _mm256_loadu_ps(rc.as_ptr());
            let v = _mm256_add_ps(_mm256_mul_ps(r, r), epsv);
            _mm256_storeu_ps(cc.as_mut_ptr(), _mm256_add_ps(_mm256_loadu_ps(cc.as_ptr()), v));
            acc = _mm256_add_ps(acc, v);
        }
        let lanes = lanes_of(acc);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for (&x, c) in row[split..].iter().zip(&mut csum[split..]) {
            let v = x * x + eps;
            *c += v;
            s += v;
        }
        s
    }
}

#[allow(clippy::too_many_arguments)]
pub fn factored_descent_row(
    x: &mut [f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, g, c);
    check_f32_aligned!(x, g, c);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { factored_descent_row_inner(x, g, c, ri, bc, inv_mean, lr, eps) }
}

// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn factored_descent_row_inner(
    x: &mut [f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    lr: f32,
    eps: f32,
) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let riv = _mm256_set1_ps(ri);
        let bcv = _mm256_set1_ps(bc);
        let imv = _mm256_set1_ps(inv_mean);
        let lrv = _mm256_set1_ps(lr);
        let epsv = _mm256_set1_ps(eps);
        let (xh, gh, ch) = (&mut x[..split], &g[..split], &c[..split]);
        for ((xc, gc), cc) in xh
            .chunks_exact_mut(LANES)
            .zip(gh.chunks_exact(LANES))
            .zip(ch.chunks_exact(LANES))
        {
            // (ri·(c·bc))·inv_mean — the scalar parse order exactly
            let u = _mm256_mul_ps(
                _mm256_mul_ps(riv, _mm256_mul_ps(_mm256_loadu_ps(cc.as_ptr()), bcv)),
                imv,
            );
            let denom = _mm256_add_ps(_mm256_sqrt_ps(u), epsv);
            let step = _mm256_div_ps(_mm256_mul_ps(lrv, _mm256_loadu_ps(gc.as_ptr())), denom);
            _mm256_storeu_ps(xc.as_mut_ptr(), _mm256_sub_ps(_mm256_loadu_ps(xc.as_ptr()), step));
        }
        for ((xj, &gj), &cj) in x[split..].iter_mut().zip(&g[split..]).zip(&c[split..]) {
            let u = ri * (cj * bc) * inv_mean;
            *xj -= lr * gj / (u.sqrt() + eps);
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn came_instability_row(
    m: &[f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    eps: f32,
    inst_c: &mut [f32],
) -> f32 {
    check_same_len!(m, g, c, inst_c);
    check_f32_aligned!(m, g, c, inst_c);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { came_instability_row_inner(m, g, c, ri, bc, inv_mean, eps, inst_c) }
}

// SAFETY: caller verified AVX2; four zipped chunks bound every access.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn came_instability_row_inner(
    m: &[f32],
    g: &[f32],
    c: &[f32],
    ri: f32,
    bc: f32,
    inv_mean: f32,
    eps: f32,
    inst_c: &mut [f32],
) -> f32 {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = m.len() - m.len() % LANES;
        let riv = _mm256_set1_ps(ri);
        let bcv = _mm256_set1_ps(bc);
        let imv = _mm256_set1_ps(inv_mean);
        let epsv = _mm256_set1_ps(eps);
        let mut acc = _mm256_setzero_ps();
        let (mh, gh, ch, ih) = (&m[..split], &g[..split], &c[..split], &mut inst_c[..split]);
        for (((mc, gc), cc), ic) in mh
            .chunks_exact(LANES)
            .zip(gh.chunks_exact(LANES))
            .zip(ch.chunks_exact(LANES))
            .zip(ih.chunks_exact_mut(LANES))
        {
            let u = _mm256_mul_ps(
                _mm256_mul_ps(riv, _mm256_mul_ps(_mm256_loadu_ps(cc.as_ptr()), bcv)),
                imv,
            );
            let u_hat = _mm256_div_ps(
                _mm256_loadu_ps(gc.as_ptr()),
                _mm256_add_ps(_mm256_sqrt_ps(u), epsv),
            );
            let d = _mm256_sub_ps(_mm256_loadu_ps(mc.as_ptr()), u_hat);
            let v = _mm256_add_ps(_mm256_mul_ps(d, d), epsv);
            _mm256_storeu_ps(ic.as_mut_ptr(), _mm256_add_ps(_mm256_loadu_ps(ic.as_ptr()), v));
            acc = _mm256_add_ps(acc, v);
        }
        let lanes = lanes_of(acc);
        let mut s = 0.0f32;
        for &l in &lanes {
            s += l;
        }
        for i in split..m.len() {
            let u = ri * (c[i] * bc) * inv_mean;
            let u_hat = g[i] / (u.sqrt() + eps);
            let d = m[i] - u_hat;
            let v = d * d + eps;
            inst_c[i] += v;
            s += v;
        }
        s
    }
}

pub fn came_descent_row(
    x: &mut [f32],
    m: &[f32],
    uc: &[f32],
    uri: f32,
    inv: f32,
    lr: f32,
    eps: f32,
) {
    check_same_len!(x, m, uc);
    check_f32_aligned!(x, m, uc);
    // SAFETY: table install is gated on AVX2 detection (mod.rs).
    unsafe { came_descent_row_inner(x, m, uc, uri, inv, lr, eps) }
}

// SAFETY: caller verified AVX2; zipped chunk windows bound every access.
#[target_feature(enable = "avx2")]
unsafe fn came_descent_row_inner(
    x: &mut [f32],
    m: &[f32],
    uc: &[f32],
    uri: f32,
    inv: f32,
    lr: f32,
    eps: f32,
) {
    // SAFETY: mutable 8-float chunks match the unaligned store width.
    unsafe {
        let split = x.len() - x.len() % LANES;
        let uriv = _mm256_set1_ps(uri);
        let invv = _mm256_set1_ps(inv);
        let lrv = _mm256_set1_ps(lr);
        let epsv = _mm256_set1_ps(eps);
        let (xh, mh, uh) = (&mut x[..split], &m[..split], &uc[..split]);
        for ((xc, mc), ucc) in xh
            .chunks_exact_mut(LANES)
            .zip(mh.chunks_exact(LANES))
            .zip(uh.chunks_exact(LANES))
        {
            // ((uri·uc)·inv) then √ then +eps — the scalar parse order
            let prod = _mm256_mul_ps(
                _mm256_mul_ps(uriv, _mm256_loadu_ps(ucc.as_ptr())),
                invv,
            );
            let denom = _mm256_add_ps(_mm256_sqrt_ps(prod), epsv);
            let step = _mm256_div_ps(_mm256_mul_ps(lrv, _mm256_loadu_ps(mc.as_ptr())), denom);
            _mm256_storeu_ps(xc.as_mut_ptr(), _mm256_sub_ps(_mm256_loadu_ps(xc.as_ptr()), step));
        }
        for ((xj, &mj), &ucj) in x[split..].iter_mut().zip(&m[split..]).zip(&uc[split..]) {
            let s = (uri * ucj * inv).sqrt() + eps;
            *xj -= lr * mj / s;
        }
    }
}
