//! Dense f32 tensor substrate.
//!
//! Powers the pure-Rust side of the framework: the CPU-only optimizer
//! implementations (`optim/`), the synthetic convex workloads for the
//! theory experiments, and the tests. Deliberately minimal — row-major
//! `Vec<f32>` + shape — because the heavy model math runs in the AOT
//! artifacts; this substrate only needs optimizer-update-shaped ops.

pub mod kernels;
pub mod ops;

use std::fmt;

/// Row-major dense f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: &[usize]) -> Tensor {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>().max(1),
            "data/shape mismatch"
        );
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product::<usize>().max(1)], shape: shape.to_vec() }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { data: vec![v; shape.iter().product::<usize>().max(1)], shape: shape.to_vec() }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { data: vec![v], shape: vec![] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product::<usize>().max(1);
        Tensor { data: (0..n).map(|i| f(i)).collect(), shape: shape.to_vec() }
    }

    // -- accessors ----------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D accessor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Reshape view (row-major, no copy). The paper's Eq. 12 reshaping
    /// relies on exactly this being free.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(
            self.data.len(),
            shape.iter().product::<usize>().max(1),
            "reshape element-count mismatch"
        );
        self.shape = shape.to_vec();
        self
    }

    // -- reductions ----------------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Squared Frobenius norm.
    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum()
    }

    pub fn norm(&self) -> f32 {
        self.sq_norm().sqrt()
    }

    /// Max |x| (the paper's ‖·‖∞).
    pub fn inf_norm(&self) -> f32 {
        // lint: allow(r2): running max is order-independent
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f32
        }
    }

    // -- elementwise (in place, allocation-free hot path) --------------------

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// self = a*self + b*other (axpby; the EMA workhorse).
    pub fn ema_inplace(&mut self, other: &Tensor, a: f32, b: f32) {
        assert_eq!(self.shape, other.shape);
        kernels::ema(&mut self.data, &other.data, a, b);
    }

    /// self += alpha * other.
    pub fn axpy_inplace(&mut self, other: &Tensor, alpha: f32) {
        self.ema_inplace(other, 1.0, alpha);
    }

    pub fn zip_inplace(&mut self, other: &Tensor, f: impl Fn(f32, f32) -> f32) {
        assert_eq!(self.shape, other.shape);
        for (x, &y) in self.data.iter_mut().zip(&other.data) {
            *x = f(*x, y);
        }
    }

    // -- elementwise (allocating) ---------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { data: self.data.iter().map(|&x| f(x)).collect(), shape: self.shape.clone() }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor {
            data: self.data.iter().zip(&other.data).map(|(&x, &y)| f(x, y)).collect(),
            shape: self.shape.clone(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn square(&self) -> Tensor {
        self.map(|x| x * x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reduce() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.sum(), 10.0);
        assert_eq!(t.sq_norm(), 30.0);
        assert_eq!(t.inf_norm(), 4.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn reshape_is_free_view() {
        let t = Tensor::new((0..12).map(|i| i as f32).collect(), &[3, 4]);
        let r = t.clone().reshape(&[2, 6]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.shape(), &[2, 6]);
    }

    #[test]
    #[should_panic]
    fn reshape_bad_count_panics() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn ema_matches_formula() {
        let mut m = Tensor::new(vec![1.0, 1.0], &[2]);
        let g = Tensor::new(vec![3.0, -1.0], &[2]);
        m.ema_inplace(&g, 0.9, 0.1);
        assert!((m.data()[0] - 1.2).abs() < 1e-6);
        assert!((m.data()[1] - 0.8).abs() < 1e-6);
    }
}
