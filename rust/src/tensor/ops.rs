//! Matrix/vector ops used by the pure-Rust optimizer implementations.
//!
//! The shapes here are optimizer-update shaped: matrix–vector products
//! against the squared momentum (`V q`, `Vᵀ p`), outer products, and a
//! blocked matmul for the synthetic workloads (softmax regression / MLP
//! in `workloads/`). All row-major, no BLAS (offline build). The inner
//! loops route through `tensor::kernels` so they share the
//! runtime-dispatched SIMD dot/axpy row primitives with the optimizer
//! hot paths (scalar/AVX2/NEON, bit-identical by contract).

use super::{kernels, Tensor};

/// y = A x for A (m, n) row-major, x (n).
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, n) = mat_dims(a);
    assert_eq!(x.len(), n, "matvec dim mismatch");
    let ad = a.data();
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        y[i] = kernels::dot(&ad[i * n..(i + 1) * n], x);
    }
    y
}

/// y = Aᵀ x for A (m, n) row-major, x (m).
pub fn matvec_t(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, n) = mat_dims(a);
    assert_eq!(x.len(), m, "matvec_t dim mismatch");
    let ad = a.data();
    let mut y = vec![0.0f32; n];
    for i in 0..m {
        kernels::axpy(&mut y, &ad[i * n..(i + 1) * n], x[i]);
    }
    y
}

/// Rank-one outer product p qᵀ as an (m, n) tensor.
pub fn outer(p: &[f32], q: &[f32]) -> Tensor {
    let mut data = Vec::with_capacity(p.len() * q.len());
    for &pi in p {
        for &qj in q {
            data.push(pi * qj);
        }
    }
    Tensor::new(data, &[p.len(), q.len()])
}

/// C = A B with cache blocking. A (m, k), B (k, n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = mat_dims(a);
    let (k2, n) = mat_dims(b);
    assert_eq!(k, k2, "matmul inner dim mismatch");
    let (ad, bd) = (a.data(), b.data());
    let mut c = vec![0.0f32; m * n];
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = ad[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                kernels::axpy(crow, &bd[kk * n..(kk + 1) * n], aik);
            }
        }
    }
    Tensor::new(c, &[m, n])
}

/// C = Aᵀ B. A (m, k), B (m, n) → (k, n). (Gradient helper.)
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = mat_dims(a);
    let (m2, n) = mat_dims(b);
    assert_eq!(m, m2, "matmul_tn dim mismatch");
    let (ad, bd) = (a.data(), b.data());
    let mut c = vec![0.0f32; k * n];
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let brow = &bd[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = arow[kk];
            if aik == 0.0 {
                continue;
            }
            kernels::axpy(&mut c[kk * n..(kk + 1) * n], brow, aik);
        }
    }
    Tensor::new(c, &[k, n])
}

/// C = A Bᵀ. A (m, k), B (n, k) → (m, n). (Gradient helper.)
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = mat_dims(a);
    let (n, k2) = mat_dims(b);
    assert_eq!(k, k2, "matmul_nt dim mismatch");
    let (ad, bd) = (a.data(), b.data());
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            c[i * n + j] = kernels::dot(arow, &bd[j * k..(j + 1) * k]);
        }
    }
    Tensor::new(c, &[m, n])
}

/// Row-wise softmax in place on an (m, n) tensor (numerically stable).
pub fn softmax_rows(t: &mut Tensor) {
    let (m, n) = mat_dims(t);
    let data = t.data_mut();
    for i in 0..m {
        let row = &mut data[i * n..(i + 1) * n];
        // lint: allow(r2): running max is order-independent
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for x in row.iter_mut() {
            *x = (*x - mx).exp();
            z += *x;
        }
        for x in row.iter_mut() {
            *x /= z;
        }
    }
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    kernels::dot(a, b)
}

fn mat_dims(t: &Tensor) -> (usize, usize) {
    assert_eq!(t.rank(), 2, "expected a matrix, got rank {}", t.rank());
    (t.shape()[0], t.shape()[1])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: &[f32], shape: &[usize]) -> Tensor {
        Tensor::new(data.to_vec(), shape)
    }

    #[test]
    fn matvec_known() {
        let a = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(matvec_t(&a, &[1.0, -1.0]), vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn matmul_known() {
        let a = t(&[1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = t(&[1.0, 1.0, 1.0, 1.0], &[2, 2]);
        assert_eq!(matmul(&a, &b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transposed_variants_agree() {
        let a = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let b = t(&[1.0, -1.0, 0.5, 2.0, 0.0, 1.0], &[2, 3]);
        // Aᵀ B directly vs via explicit transpose through matmul
        let at = t(&[1.0, 4.0, 2.0, 5.0, 3.0, 6.0], &[3, 2]);
        assert_eq!(matmul_tn(&a, &b).data(), matmul(&at, &b).data());
        // A Bᵀ
        let bt = t(&[1.0, 2.0, -1.0, 0.0, 0.5, 1.0], &[3, 2]);
        let nt = matmul_nt(&a, &b);
        let direct = matmul(&a, &bt);
        for (x, y) in nt.data().iter().zip(direct.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn outer_known() {
        let o = outer(&[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(o.data(), &[3.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut a = t(&[1.0, 2.0, 3.0, -1.0, 0.0, 1.0], &[2, 3]);
        softmax_rows(&mut a);
        for i in 0..2 {
            let s: f32 = (0..3).map(|j| a.at2(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }
}
