#!/usr/bin/env bash
# Single-machine TCP launcher for the shard engine: runs `alada
# shard-train` as N cooperating OS processes on loopback (this process
# becomes rank 0 and spawns the other N-1; they rendezvous on an
# OS-assigned port). Extra flags pass through to shard-train.
#
#   scripts/shard_tcp.sh 4 --opt alada --steps 200 --batch 32
set -euo pipefail
cd "$(dirname "$0")/.."
n="${1:?usage: shard_tcp.sh <nprocs> [shard-train flags...]}"
shift
exec cargo run --release -q -- shard-train --transport tcp --spawn "$n" "$@"
