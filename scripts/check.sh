#!/usr/bin/env bash
# Lint gate: formatting + clippy with warnings denied, then the tier-1
# tests. Run from the repo root; CI and pre-push hooks call this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== alada lint (project static analysis) =="
# The in-tree determinism/concurrency pass (rust/src/lint/): unordered
# maps, float reductions, wall-clock reads, panics in the transport and
# serve request paths, unstamped transport errors, narrowing casts,
# locks held across blocking calls, SAFETY-less unsafe. Exits non-zero
# with file:line diagnostics on any violation.
cargo run -q -- lint rust/src

echo "== cargo test =="
cargo test -q

echo "== cargo test (ALADA_SIMD=scalar: every suite through the oracle backend) =="
# the tier-1 suites must hold under both dispatch decisions — the SIMD
# backends are bit-identical to scalar by contract, and this run is the
# end-to-end proof (the per-kernel pin lives in rust/tests/simd_parity.rs)
ALADA_SIMD=scalar cargo test -q

echo "== tcp smoke: 2-process loopback parity vs inproc =="
tmp="$(mktemp -d)"
# every background pid lands here; the trap murders whatever is left so
# an assertion failure never strands servers or training processes
PIDS=()
cleanup() {
    for pid in ${PIDS[@]+"${PIDS[@]}"}; do kill -9 "$pid" 2>/dev/null || true; done
    rm -rf "$tmp"
}
trap cleanup EXIT
common=(--opt alada --steps 6 --batch 8 --dim 8 --hidden 12 --depth 2 --bucket-kb 1 --seed 3)
cargo run -q -- shard-train --ranks 2 "${common[@]}" --dump-params "$tmp/inproc.bin"
cargo run -q -- shard-train --transport tcp --spawn 2 "${common[@]}" --dump-params "$tmp/tcp.bin"
cmp "$tmp/inproc.bin" "$tmp/tcp.bin"
echo "   tcp final params byte-identical to inproc"

echo "== simd dispatch gate: detected backend vs forced scalar, cmp-identical params =="
# whatever backend the host dispatches to must produce the byte-identical
# training run as the forced scalar oracle — the kernel bit-identity
# contract checked at the whole-binary level. `features` records which
# backend the native side actually used.
cargo run -q -- features
simd_ab=(--opt alada --steps 6 --batch 8 --dim 6 --hidden 10 --depth 1 \
         --bucket-kb 1 --seed 23 --schedule const:0.005 --same-batch)
cargo run -q -- shard-train --ranks 2 "${simd_ab[@]}" --dump-params "$tmp/simd_native.bin"
ALADA_SIMD=scalar cargo run -q -- shard-train --ranks 2 "${simd_ab[@]}" \
    --dump-params "$tmp/simd_scalar.bin"
cmp "$tmp/simd_native.bin" "$tmp/simd_scalar.bin"
echo "   native-dispatch final params byte-identical to the forced-scalar run"

echo "== elastic resume smoke: save @ 2 tcp procs, resume @ 4, cmp vs uninterrupted 4-proc run =="
# --same-batch makes the trajectory rank-count-invariant (every rank
# computes the full global batch; the tree mean of identical copies is
# exact at power-of-two rank counts), so a checkpoint saved at 2 ranks
# must resume at 4 ranks onto the byte-identical uninterrupted result.
# The explicit const schedule keeps the 4-step save run on the same
# learning rates as the 8-step runs (the default dim:LR:STEPS horizon
# would differ).
elastic=(--opt alada --batch 8 --dim 6 --hidden 10 --depth 1 --bucket-kb 1 \
         --seed 5 --schedule const:0.005 --same-batch)
cargo run -q -- shard-train --transport tcp --spawn 4 --steps 8 "${elastic[@]}" \
    --dump-params "$tmp/full4.bin"
cargo run -q -- shard-train --transport tcp --spawn 2 --steps 4 "${elastic[@]}" \
    --save "$tmp/ckpt"
test -f "$tmp/ckpt/manifest.json"
cargo run -q -- shard-train --transport tcp --spawn 4 --steps 8 "${elastic[@]}" \
    --resume "$tmp/ckpt" --dump-params "$tmp/resume4.bin"
cmp "$tmp/full4.bin" "$tmp/resume4.bin"
echo "   save@2/resume@4 final params byte-identical to the uninterrupted 4-proc run"

echo "== serve smoke: batched HTTP inference over a sharded checkpoint =="
# train + save a tiny 2-rank checkpoint, then serve it on an ephemeral
# port; the served tokens must byte-match the one-shot `generate` oracle
# (the batched path is bit-identical to solo decode, by construction).
cargo run -q -- shard-train --ranks 2 --opt alada --steps 4 --batch 8 --dim 6 \
    --hidden 10 --depth 1 --bucket-kb 1 --seed 7 --save "$tmp/serve_ckpt"
test -f "$tmp/serve_ckpt/manifest.json"
want="$(cargo run -q -- generate --ckpt "$tmp/serve_ckpt" --tokens 3,5,2 --max-new 4)"
cargo run -q -- serve --ckpt "$tmp/serve_ckpt" --addr 127.0.0.1:0 \
    >"$tmp/serve.log" 2>&1 &
serve_pid=$!
PIDS+=("$serve_pid")
for _ in $(seq 1 100); do
    grep -q "serving on http://" "$tmp/serve.log" && break
    sleep 0.1
done
base="$(grep -m1 -o 'http://[0-9.]*:[0-9]*' "$tmp/serve.log")"
test -n "$base"
curl -fsS "$base/healthz" | grep -q '"status":"ok"'
resp="$(curl -fsS -X POST "$base/v1/generate" -d '{"tokens":[3,5,2],"max_new":4}')"
# the oracle prints exactly {"tokens":[..]}; the served body must carry
# the same "tokens":[..] member bit-for-bit
want_tokens="${want#\{}"; want_tokens="${want_tokens%\}}"
grep -qF "$want_tokens" <<<"$resp"
code="$(curl -s -o /dev/null -w '%{http_code}' -X POST "$base/v1/generate" -d '{oops')"
test "$code" = "400"
# graceful shutdown: SIGTERM must drain and print the final stats line
kill -TERM "$serve_pid"
wait "$serve_pid"
grep -q "serve: final stats" "$tmp/serve.log"
echo "   served tokens byte-identical to the one-shot generate oracle; SIGTERM drained cleanly"

echo "== export smoke: weights-only artifact decodes identically =="
cargo run -q -- export --ckpt "$tmp/serve_ckpt" --out "$tmp/weights.alw"
got="$(cargo run -q -- generate --ckpt "$tmp/weights.alw" --tokens 3,5,2 --max-new 4)"
test "$got" = "$want"
echo "   exported artifact generate == checkpoint generate"

echo "== chaos gate: kill -9 one of 4 supervised workers mid-run; restart @ 3 matches the uninterrupted 3-proc run =="
# --same-batch + --quant-grads makes the trajectory rank-count-invariant
# for 1..4 ranks (quantized grads sum exactly in the tree for k <= 4), so
# the supervised run — started at 4 procs, one murdered, re-rendezvoused
# at 3, resumed from the last committed checkpoint — must land on the
# byte-identical params of a 3-proc run that never saw a fault.
chaos=(--opt alada --batch 8 --dim 6 --hidden 10 --depth 1 --bucket-kb 1 \
       --seed 11 --schedule const:0.005 --same-batch --quant-grads --steps 10)
cargo run -q -- shard-train --transport tcp --spawn 3 "${chaos[@]}" \
    --dump-params "$tmp/ref3.bin"
cargo run -q -- shard-train --transport tcp --spawn 4 --supervise --max-restarts 2 \
    --save "$tmp/chaos_ckpt" --save-every 2 --step-sleep-ms 250 \
    --setup-timeout-s 20 --progress-timeout-s 10 "${chaos[@]}" \
    --dump-params "$tmp/chaos.bin" >"$tmp/chaos.log" 2>&1 &
chaos_pid=$!
PIDS+=("$chaos_pid")
# wait for the first committed checkpoint so the restart exercises resume
for _ in $(seq 1 300); do
    test -f "$tmp/chaos_ckpt/manifest.json" && break
    sleep 0.1
done
test -f "$tmp/chaos_ckpt/manifest.json"
# the launcher prints each worker's pid; murder rank 1 mid-run
victim="$(grep -m1 -o 'worker rank=1 pid=[0-9]*' "$tmp/chaos.log" | grep -o '[0-9]*$')"
test -n "$victim"
kill -9 "$victim"
wait "$chaos_pid"
grep -q "re-rendezvous (generation 1)" "$tmp/chaos.log"
grep -q "generation 1: world size 3" "$tmp/chaos.log"
cmp "$tmp/ref3.bin" "$tmp/chaos.bin"
echo "   supervised 4→3 restart final params byte-identical to the uninterrupted 3-proc run"

echo "== guardrail gate: injected NaN @ step 2 is skipped in lockstep; 1-proc and 2-proc params identical =="
# --same-batch + --quant-grads makes the trajectory rank-count-invariant,
# so a NaN landing in ONE rank's gradient must produce the byte-identical
# skip at every rank count — the sentinel's flag reduce is what keeps the
# decision mesh-wide instead of per-rank.
guard=(--opt alada --batch 8 --dim 6 --hidden 10 --depth 1 --bucket-kb 1 \
       --seed 13 --schedule const:0.005 --same-batch --quant-grads --steps 8)
cargo run -q -- shard-train --ranks 1 "${guard[@]}" --inject nan@2 --on-anomaly skip \
    --dump-params "$tmp/skip1.bin" 2>"$tmp/skip1.log"
grep -q "update skipped" "$tmp/skip1.log"
cargo run -q -- shard-train --transport tcp --spawn 2 "${guard[@]}" --inject nan@2 \
    --on-anomaly skip --dump-params "$tmp/skip2.bin"
cmp "$tmp/skip1.bin" "$tmp/skip2.bin"
# and the skip really zeroed an update: a clean run must end elsewhere
cargo run -q -- shard-train --ranks 1 "${guard[@]}" --dump-params "$tmp/clean1.bin"
if cmp -s "$tmp/skip1.bin" "$tmp/clean1.bin"; then
    echo "skip run unexpectedly matches the clean run — the NaN never landed" >&2
    exit 1
fi
echo "   NaN@2 skipped in lockstep; 1-proc inproc == 2-proc tcp, both differ from clean"

echo "== chaos gate 2: corrupt TCP frame under --supervise; auto-recovery matches the clean run =="
# flip@5:1 flips one bit of a rank-1 frame after its checksum was
# computed; the receiver surfaces a typed Corrupt error, both workers
# unwind, re-rendezvous (nobody died, so generation 1 keeps world size
# 2), resume from the step-4 commit, and must land on the byte-identical
# params of a run that never saw the fault. Injection latches per
# process, so the replayed step 5 does not re-fire.
flip=(--opt alada --batch 8 --dim 6 --hidden 10 --depth 1 --bucket-kb 1 \
      --seed 17 --schedule const:0.005 --steps 8)
cargo run -q -- shard-train --transport tcp --spawn 2 "${flip[@]}" \
    --dump-params "$tmp/flip_ref.bin"
cargo run -q -- shard-train --transport tcp --spawn 2 --supervise --max-restarts 2 \
    --save "$tmp/flip_ckpt" --save-every 2 "${flip[@]}" --inject flip@5:1 \
    --dump-params "$tmp/flip.bin" >"$tmp/flip.log" 2>&1
grep -q "re-rendezvous (generation 1)" "$tmp/flip.log"
grep -q "generation 1: world size 2" "$tmp/flip.log"
cmp "$tmp/flip_ref.bin" "$tmp/flip.bin"
echo "   corrupt frame detected, supervised restart resumed; final params byte-identical to the clean run"
