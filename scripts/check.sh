#!/usr/bin/env bash
# Lint gate: formatting + clippy with warnings denied, then the tier-1
# tests. Run from the repo root; CI and pre-push hooks call this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo test =="
cargo test -q

echo "== tcp smoke: 2-process loopback parity vs inproc =="
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT
common=(--opt alada --steps 6 --batch 8 --dim 8 --hidden 12 --depth 2 --bucket-kb 1 --seed 3)
cargo run -q -- shard-train --ranks 2 "${common[@]}" --dump-params "$tmp/inproc.bin"
cargo run -q -- shard-train --transport tcp --spawn 2 "${common[@]}" --dump-params "$tmp/tcp.bin"
cmp "$tmp/inproc.bin" "$tmp/tcp.bin"
echo "   tcp final params byte-identical to inproc"
