#!/usr/bin/env bash
# Lint gate: formatting + clippy with warnings denied, then the tier-1
# tests. Run from the repo root; CI and pre-push hooks call this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo test =="
cargo test -q

echo "== tcp smoke: 2-process loopback parity vs inproc =="
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT
common=(--opt alada --steps 6 --batch 8 --dim 8 --hidden 12 --depth 2 --bucket-kb 1 --seed 3)
cargo run -q -- shard-train --ranks 2 "${common[@]}" --dump-params "$tmp/inproc.bin"
cargo run -q -- shard-train --transport tcp --spawn 2 "${common[@]}" --dump-params "$tmp/tcp.bin"
cmp "$tmp/inproc.bin" "$tmp/tcp.bin"
echo "   tcp final params byte-identical to inproc"

echo "== elastic resume smoke: save @ 2 tcp procs, resume @ 4, cmp vs uninterrupted 4-proc run =="
# --same-batch makes the trajectory rank-count-invariant (every rank
# computes the full global batch; the tree mean of identical copies is
# exact at power-of-two rank counts), so a checkpoint saved at 2 ranks
# must resume at 4 ranks onto the byte-identical uninterrupted result.
# The explicit const schedule keeps the 4-step save run on the same
# learning rates as the 8-step runs (the default dim:LR:STEPS horizon
# would differ).
elastic=(--opt alada --batch 8 --dim 6 --hidden 10 --depth 1 --bucket-kb 1 \
         --seed 5 --schedule const:0.005 --same-batch)
cargo run -q -- shard-train --transport tcp --spawn 4 --steps 8 "${elastic[@]}" \
    --dump-params "$tmp/full4.bin"
cargo run -q -- shard-train --transport tcp --spawn 2 --steps 4 "${elastic[@]}" \
    --save "$tmp/ckpt"
test -f "$tmp/ckpt/manifest.json"
cargo run -q -- shard-train --transport tcp --spawn 4 --steps 8 "${elastic[@]}" \
    --resume "$tmp/ckpt" --dump-params "$tmp/resume4.bin"
cmp "$tmp/full4.bin" "$tmp/resume4.bin"
echo "   save@2/resume@4 final params byte-identical to the uninterrupted 4-proc run"
