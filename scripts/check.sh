#!/usr/bin/env bash
# Lint gate: formatting + clippy with warnings denied, then the tier-1
# tests. Run from the repo root; CI and pre-push hooks call this.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (all targets, -D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== cargo test =="
cargo test -q
