#!/usr/bin/env bash
# ThreadSanitizer pass over the concurrency-heavy suites. TSan needs a
# nightly toolchain with rust-src (build-std recompiles core with the
# sanitizer runtime), so this is an opt-in deep check, not part of the
# tier-1 gate — check.sh covers the same code with the static lint
# (rule r7) instead. Skips cleanly, exit 0, when the toolchain pieces
# are missing so CI images without rustup stay green.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v rustup >/dev/null 2>&1; then
    echo "sanitize: rustup not installed — skipping TSan pass"
    exit 0
fi
if ! rustup toolchain list | grep -q '^nightly'; then
    echo "sanitize: no nightly toolchain — skipping TSan pass"
    exit 0
fi
if ! rustup component list --toolchain nightly 2>/dev/null | grep -q 'rust-src.*(installed)'; then
    echo "sanitize: nightly rust-src component missing — skipping TSan pass"
    exit 0
fi

host="$(rustc -vV | sed -n 's/^host: //p')"
echo "== TSan: transport conformance + serve HTTP (target $host) =="
# The two suites that actually cross threads: the TCP transport's
# join-round/rendezvous machinery and the serve batcher's cutter/worker
# pool. One test thread at a time so TSan interleaving reports stay
# attributable.
RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
    cargo +nightly test -Zbuild-std --target "$host" \
    --test transport_conformance --test serve_http -- --test-threads=1
echo "sanitize: TSan pass clean"
