"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including ragged row-block tails), step
parities, and decay parameters. This is the CORE correctness signal for
the compiled hot path: the same kernel code is lowered into every
train_* artifact the Rust runtime executes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adafactor as k_adafactor
from compile.kernels import adam as k_adam
from compile.kernels import alada as k_alada
from compile.kernels import common, ref

DIMS = st.integers(min_value=1, max_value=97)
BETAS = st.sampled_from([0.0, 0.5, 0.9, 0.99, 0.999])
STEPS = st.integers(min_value=0, max_value=7)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, t=STEPS, beta1=BETAS, beta2=BETAS)
def test_alada_kernel_matches_ref(m, n, t, beta1, beta2):
    rng = np.random.default_rng(m * 1000 + n * 10 + t)
    x, g, mom = rand(rng, m, n), rand(rng, m, n), rand(rng, m, n) * 0.1
    v0, p, q = ref.alada_init_ref(g)
    p = p + jnp.asarray(rng.uniform(0.01, 0.1, m), jnp.float32)
    q = q + jnp.asarray(rng.uniform(0.01, 0.1, n), jnp.float32)
    out_k = k_alada.alada_matrix_step(
        x, g, mom, p, q, v0, jnp.int32(t), beta1, beta2, 1e-16, 1e-3)
    out_r = ref.alada_step_ref(x, g, mom, p, q, v0, t, beta1, beta2, 1e-16, 1e-3)
    for a, b, name in zip(out_k, out_r, ["x", "m", "p", "q"]):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=2e-5, err_msg=name)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, t=STEPS, beta1=BETAS, beta2=BETAS)
def test_adam_kernel_matches_ref(m, n, t, beta1, beta2):
    rng = np.random.default_rng(m * 991 + n * 7 + t)
    x, g, mom = rand(rng, m, n), rand(rng, m, n), rand(rng, m, n) * 0.1
    u = jnp.abs(rand(rng, m, n)) * 0.01
    out_k = k_adam.adam_matrix_step(x, g, mom, u, jnp.int32(t), beta1, beta2, 1e-8, 1e-3)
    out_r = ref.adam_step_ref(x, g, mom, u, t, beta1, beta2, 1e-8, 1e-3)
    for a, b, name in zip(out_k, out_r, ["x", "m", "u"]):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=2e-5, err_msg=name)


@settings(max_examples=25, deadline=None)
@given(m=DIMS, n=DIMS, t=STEPS, beta2=BETAS)
def test_adafactor_kernel_matches_ref(m, n, t, beta2):
    rng = np.random.default_rng(m * 883 + n * 3 + t)
    x, g = rand(rng, m, n), rand(rng, m, n)
    r = jnp.abs(rand(rng, m)) * 0.01
    c = jnp.abs(rand(rng, n)) * 0.01
    out_k = k_adafactor.adafactor_matrix_step(x, g, r, c, jnp.int32(t), beta2, 1e-8, 1e-3)
    out_r = ref.adafactor_step_ref(x, g, r, c, t, beta2, 1e-8, 1e-3)
    for a, b, name in zip(out_k, out_r, ["x", "r", "c"]):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=2e-5, err_msg=name)


def test_row_block_respects_vmem_budget():
    for (m, n) in [(8, 8), (1024, 1024), (50000, 17), (7, 131072)]:
        bm = common.row_block(m, n)
        assert 1 <= bm <= m
        assert bm * n <= max(common._VMEM_TILE_ELEMS, n)  # one tile fits


def test_vmem_footprint_fits_tpu_vmem():
    # DESIGN.md hardware-adaptation claim: tiles + slivers << 16 MiB
    for (m, n) in [(1024, 1024), (4096, 512), (50257, 768)]:
        fp = common.vmem_footprint_bytes(m, n, n_mats=3, n_vecs=2)
        assert fp < 4 * 1024 * 1024, f"{m}x{n}: {fp}"


def test_descent_never_materialises_u():
    """The descent kernel reconstructs p q^T per tile; numerical equality
    with the explicit outer-product reference is the proof it does the
    same math without the HBM intermediate."""
    rng = np.random.default_rng(0)
    m, n = 65, 33  # ragged: exercises the padded final row block
    x = rand(rng, m, n)
    m_hat = rand(rng, m, n)
    p = jnp.abs(rand(rng, m)) + 0.1
    q = jnp.abs(rand(rng, n)) + 0.1
    got = k_alada.descent(x, m_hat, p, q, jnp.float32(0.01), 0.9, jnp.float32(3), 1e-16, 1e-3)
    want = ref.alada_descent_ref(x, m_hat, p, q, 0.01, 0.9, 3, 1e-16, 1e-3)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


@pytest.mark.parametrize("m,n", [(1, 1), (1, 64), (64, 1), (8, 8), (33, 129)])
def test_factor_candidates_edge_shapes(m, n):
    rng = np.random.default_rng(m * 7 + n)
    m_hat = rand(rng, m, n)
    p = jnp.abs(rand(rng, m)) + 0.1
    q = jnp.abs(rand(rng, n)) + 0.1
    p_num, q_num = k_alada.factor_candidates(m_hat, p, q)
    v = m_hat * m_hat
    np.testing.assert_allclose(p_num, v @ q, rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(q_num, v.T @ p, rtol=3e-5, atol=3e-6)
