"""AOT pipeline tests: HLO text emission, manifest integrity, init dumps."""

import json
import os

import jax
import numpy as np
import pytest

from compile.aot import dump_init, lower_spec
from compile.config import MODEL_SIZES
from compile.train_step import build_eval_step, build_train_step


@pytest.fixture(scope="module")
def tmp_art(tmp_path_factory):
    return str(tmp_path_factory.mktemp("artifacts"))


def test_lowered_hlo_is_text_and_parseable_shape(tmp_art):
    spec = build_train_step("lm", MODEL_SIZES["tiny"], "alada", 2)
    entry = lower_spec(spec, tmp_art)
    text = open(os.path.join(tmp_art, entry["file"])).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # flat-packed signature: exactly 5 params for the lm task
    assert len(entry["inputs"]) == 5
    assert entry["inputs"][0]["name"] == "params"
    assert entry["meta"]["param_elems"] == entry["inputs"][0]["shape"][0]


def test_manifest_tables_cover_every_param(tmp_art):
    spec = build_train_step("cls", MODEL_SIZES["tiny"], "adam", 2)
    entry = lower_spec(spec, tmp_art)
    covered = sum(int(np.prod(p["shape"])) if p["shape"] else 1
                  for p in entry["param_table"])
    assert covered == entry["meta"]["param_elems"]
    covered_s = sum(int(np.prod(p["shape"])) if p["shape"] else 1
                    for p in entry["state_table"])
    assert covered_s == entry["meta"]["state_elems"]


def test_init_dump_length_matches_param_elems(tmp_art):
    entry = dump_init("lm", "tiny", tmp_art)
    size = os.path.getsize(os.path.join(tmp_art, entry["name"]))
    total = sum(int(np.prod(p["shape"])) for p in entry["params"])
    assert size == total * 4


def test_init_dump_is_deterministic(tmp_art):
    dump_init("lm", "tiny", tmp_art)
    a = open(os.path.join(tmp_art, "init_lm_tiny.bin"), "rb").read()
    dump_init("lm", "tiny", tmp_art)
    b = open(os.path.join(tmp_art, "init_lm_tiny.bin"), "rb").read()
    assert a == b


def test_eval_spec_has_no_state(tmp_art):
    spec = build_eval_step("lm", MODEL_SIZES["tiny"], 2)
    assert spec.state_table == []
    assert [n for n, _, _ in spec.inputs] == ["params", "batch.tokens"]


def test_repo_manifest_if_built():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    man = json.load(open(path))
    names = {a["name"] for a in man["artifacts"]}
    for task in ("lm", "cls", "mt"):
        for opt in ("adam", "adafactor", "alada"):
            assert f"train_{task}_small_{opt}" in names
    assert any(n.startswith("train_lm_base") for n in names)
