"""L2 optimizer-layer tests: full-pytree Alada/Adam/Adafactor semantics.

Checks the paper-visible invariants at the optimizer (not kernel) level:
alternation parity, t=0 initialisation, Prop. 1 error decrease, the
Eq. 12 reshape, pallas-path == ref-path, and the SIV-C decay mapping.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.optim_jax import make_optimizer
from compile.pytree import flatten, unflatten


def tree_allclose(a, b, rtol=3e-5, atol=3e-6):
    fa, fb = flatten(a), flatten(b)
    assert [p for p, _ in fa] == [p for p, _ in fb]
    for (p, x), (_, y) in zip(fa, fb):
        np.testing.assert_allclose(x, y, rtol=rtol, atol=atol, err_msg=p)


def small_tree(rng):
    return {
        "emb": jnp.asarray(rng.standard_normal((24, 16)), jnp.float32),
        "layer": {
            "w": jnp.asarray(rng.standard_normal((16, 16)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32),
        },
    }


def grads_like(tree, rng):
    paths = [p for p, _ in flatten(tree)]
    leaves = [jnp.asarray(rng.standard_normal(l.shape), jnp.float32) * 0.1
              for _, l in flatten(tree)]
    return unflatten(paths, leaves)


def test_alada_pallas_path_equals_ref_path():
    rng = np.random.default_rng(0)
    params = small_tree(rng)
    opt_k = make_optimizer("alada", use_pallas=True)
    opt_r = make_optimizer("alada", use_pallas=False)
    sk, sr = opt_k.init(params), opt_r.init(params)
    pk, pr = params, params
    for i in range(5):
        g = grads_like(params, rng)
        pk, sk = opt_k.update(g, pk, sk, 1e-3)
        pr, sr = opt_r.update(g, pr, sr, 1e-3)
    tree_allclose(pk, pr)
    tree_allclose(sk, sr)


def test_alada_alternation_parity_at_tree_level():
    rng = np.random.default_rng(1)
    params = small_tree(rng)
    opt = make_optimizer("alada", use_pallas=False)
    state = opt.init(params)
    g = grads_like(params, rng)
    params1, state1 = opt.update(g, params, state, 1e-3)   # t=0: p updated
    p1 = state1["slots"]["emb"]["p"]
    q1 = state1["slots"]["emb"]["q"]
    params2, state2 = opt.update(g, params1, state1, 1e-3)  # t=1: q updated
    np.testing.assert_array_equal(state2["slots"]["emb"]["p"], p1)
    assert not np.allclose(state2["slots"]["emb"]["q"], q1)


def test_alada_t0_initialisation_matches_paper():
    rng = np.random.default_rng(2)
    params = small_tree(rng)
    opt = make_optimizer("alada", use_pallas=False)
    state = opt.init(params)
    g = grads_like(params, rng)
    _, state1 = opt.update(g, params, state, 1e-3)
    gm = g["emb"]
    v0 = float(jnp.sum(gm * gm) / gm.size)
    assert abs(float(state1["slots"]["emb"]["v0"][0]) - v0) < 1e-6 * max(v0, 1)


def test_vector_params_use_eq12_degenerate_split():
    rng = np.random.default_rng(3)
    params = small_tree(rng)
    opt = make_optimizer("alada", use_pallas=False)
    state = opt.init(params)
    slot = state["slots"]["layer/b"]
    assert slot["p"].shape == (1,)
    assert slot["q"].shape == (16,)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=12), min_size=0, max_size=4))
def test_balanced_split_properties(shape):
    m, n = ref.balanced_split(shape)
    total = int(np.prod(shape)) if shape else 1
    assert m * n == total
    # no split can be more balanced
    left = 1
    best = abs(m - n)
    for j in range(len(shape) + 1):
        assert abs(left - total // left) >= best or left * (total // left) != total or True
        gap = abs(left - total // left)
        assert gap >= best or left * (total // left) != total
        if j < len(shape):
            left *= shape[j]


def test_prop1_error_decreases_under_projection():
    """Proposition 1 at the jnp level: ||V - U_{t+1}|| <= ||V - U_t||."""
    rng = np.random.default_rng(4)
    v = jnp.asarray(rng.standard_normal((12, 9)) ** 2 + 0.01, jnp.float32)
    p = jnp.asarray(rng.uniform(0.1, 1.0, 12), jnp.float32)
    q = jnp.asarray(rng.uniform(0.1, 1.0, 9), jnp.float32)
    m_hat = jnp.sqrt(v)
    for t in range(8):
        err_before = float(jnp.linalg.norm(v - p[:, None] * q[None, :]))
        # beta2=0 gives the pure projection step of the proposition
        p, q = ref.alada_factor_ref(m_hat, p, q, 0.0, t, 1e-16)
        err_after = float(jnp.linalg.norm(v - p[:, None] * q[None, :]))
        assert err_after <= err_before * (1 + 1e-5), f"t={t}: {err_before}->{err_after}"


def test_decay_mapping_s4c():
    """SIV-C: (1-beta2)(1-beta1)^2 in Alada should equal 1-beta2_adam.
    With beta1=0.9: beta2=0.9 maps to adam beta2=0.999."""
    beta1, beta2 = 0.9, 0.9
    assert abs((1 - beta2) * (1 - beta1) ** 2 - (1 - 0.999)) < 1e-12


def test_adam_and_adafactor_tree_updates_finite():
    rng = np.random.default_rng(5)
    params = small_tree(rng)
    for name in ["adam", "adafactor"]:
        opt = make_optimizer(name)
        state = opt.init(params)
        p = params
        for _ in range(3):
            g = grads_like(params, rng)
            p, state = opt.update(g, p, state, 1e-3)
        for path, leaf in flatten(p):
            assert np.isfinite(np.asarray(leaf)).all(), f"{name}:{path}"


def test_alada_state_overhead_is_sublinear():
    rng = np.random.default_rng(6)
    params = {"big": jnp.zeros((256, 192), jnp.float32)}
    opt = make_optimizer("alada")
    state = opt.init(params)
    slot = state["slots"]["big"]
    overhead = slot["p"].size + slot["q"].size + slot["v0"].size
    assert overhead == 256 + 192 + 1  # O(m+n), M excluded (grad slot)
