"""L2 model tests: shapes, masking semantics, trainability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.config import MODEL_SIZES
from compile.train_step import Packer, build_train_step, init_example_params

CFG = MODEL_SIZES["tiny"]


def test_param_count_formula_matches_reality():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for _, l in __import__("compile.pytree", fromlist=["flatten"]).flatten(params))
    assert total == CFG.param_count()


def test_forward_shapes():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    tokens = jnp.ones((3, CFG.max_seq), jnp.int32)
    h = M.forward(params, tokens, CFG)
    assert h.shape == (3, CFG.max_seq, CFG.d_model)
    logits = M.lm_logits(params, tokens, CFG)
    assert logits.shape == (3, CFG.max_seq, CFG.vocab)


def test_lm_loss_starts_near_uniform():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(1, CFG.vocab, (4, CFG.max_seq)), jnp.int32)
    loss, _, _ = M.lm_loss(params, tokens, CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_pad_positions_are_ignored():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = np.asarray(rng.integers(1, CFG.vocab, (2, CFG.max_seq)), np.int32)
    full_loss = M.lm_loss(params, jnp.asarray(tokens), CFG)
    # padding the tail must change the count, not blow up the loss
    tokens_pad = tokens.copy()
    tokens_pad[:, CFG.max_seq // 2:] = M.PAD_ID
    loss_pad, total_pad, count_pad = M.lm_loss(params, jnp.asarray(tokens_pad), CFG)
    assert count_pad < full_loss[2]
    assert np.isfinite(float(loss_pad))


def test_mt_loss_mask_restricts_positions():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(1, CFG.vocab, (2, CFG.max_seq)), jnp.int32)
    mask_none = jnp.zeros((2, CFG.max_seq), jnp.float32)
    mask_half = mask_none.at[:, CFG.max_seq // 2:].set(1.0)
    _, total_none, count_none = M.mt_loss(params, tokens, mask_none, CFG)
    _, total_half, count_half = M.mt_loss(params, tokens, mask_half, CFG)
    assert float(count_none) == 0.0
    assert float(total_none) == 0.0
    assert float(count_half) > 0


def test_cls_logits_shape_and_loss():
    params = M.init_params(CFG, jax.random.PRNGKey(0), n_classes=4)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(1, CFG.vocab, (5, CFG.max_seq)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 4, (5,)), jnp.int32)
    logits = M.cls_logits(params, tokens, CFG)
    assert logits.shape == (5, 4)
    loss, _, _ = M.cls_loss(params, tokens, labels, CFG)
    assert abs(float(loss) - np.log(4)) < 0.5


def test_train_step_reduces_loss_all_optimizers():
    rng = np.random.default_rng(4)
    tokens = jnp.asarray(rng.integers(1, CFG.vocab, (4, CFG.max_seq)), jnp.int32)
    for opt in ["adam", "adafactor", "alada"]:
        spec = build_train_step("lm", CFG, opt, 4, use_pallas=False)
        step = jax.jit(spec.fn)
        params = Packer(init_example_params(CFG, 0)).pack(init_example_params(CFG, 0))
        state = jnp.zeros((spec.meta["state_elems"],), jnp.float32)
        t = jnp.zeros((1,), jnp.int32)
        lr = jnp.asarray([1e-2 if opt != "adafactor" else 3e-2], jnp.float32)
        first = None
        for i in range(10):
            params, state, t, loss = step(params, state, t, tokens, lr)
            if first is None:
                first = float(loss[0])
        assert float(loss[0]) < first * 0.9, f"{opt}: {first} -> {float(loss[0])}"


def test_packer_round_trip():
    from compile.pytree import flatten
    params = init_example_params(CFG, 0)
    pack = Packer(params)
    vec = pack.pack(params)
    back = pack.unpack(vec)
    for (pa, la), (pb, lb) in zip(flatten(params), flatten(back)):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
