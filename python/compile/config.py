"""Model / optimizer configurations shared by the compile path.

These mirror the Rust-side config system (rust/src/config). The AOT
pipeline (aot.py) lowers one fused train step per (task, size, optimizer)
triple; the names here are the artifact-name components the Rust runtime
looks up in artifacts/manifest.json.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyper-parameters."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self, n_classes: int = 0) -> int:
        """Exact trainable-parameter count (tied input/output embedding)."""
        d, f = self.d_model, self.d_ff
        per_layer = 4 * d * d + 2 * d * f + 4 * d + f + d  # attn + mlp + 2 LN
        total = self.vocab * d + self.max_seq * d + self.n_layers * per_layer
        total += 2 * d  # final LN
        if n_classes:
            total += d * n_classes + n_classes
        return total


# Sizes. `tiny` is the pytest/CI size; `small` drives the figure/table
# experiments; `base` is the end-to-end example (multi-million params);
# the paper-shape configs exist only for the Table-IV memory model (their
# layer dimensions match GPT2-Small/XL and T5-Small, and are lowered
# shape-only, never trained here).
MODEL_SIZES = {
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=128, max_seq=32),
    "small": ModelConfig("small", vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=384, max_seq=64),
    "base": ModelConfig("base", vocab=1024, d_model=256, n_layers=6, n_heads=8, d_ff=1024, max_seq=128),
}


@dataclass(frozen=True)
class OptimConfig:
    """Optimizer selection + decay parameters (paper §VI-A defaults)."""

    name: str  # adam | adafactor | alada
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    @staticmethod
    def default(name: str) -> "OptimConfig":
        if name == "adam":
            return OptimConfig("adam", beta1=0.9, beta2=0.999, eps=1e-8)
        if name == "adafactor":
            # paper: first moment disabled, beta2 = 0.999
            return OptimConfig("adafactor", beta1=0.0, beta2=0.999, eps=1e-8)
        if name == "alada":
            # paper §IV-C: beta1 = beta2 = 0.9, eps = 1e-16
            return OptimConfig("alada", beta1=0.9, beta2=0.9, eps=1e-16)
        raise ValueError(f"unknown optimizer {name!r}")


OPTIMIZERS = ("adam", "adafactor", "alada")

# Tasks: decoder-only LM, sequence classification, prefix-LM translation.
TASKS = ("lm", "cls", "mt")

# Classification head width for the cls task (synthetic GLUE-like tasks
# have at most 3 classes; we lower with 4 to keep one artifact).
N_CLASSES = 4
