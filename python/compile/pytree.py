"""Deterministic pytree flattening shared by the AOT pipeline and tests.

The Rust runtime is manifest-driven: it marshals flat buffer lists in
exactly the order produced here. Nested dicts are flattened depth-first
with *sorted* keys, paths joined with '.', so the ordering is a pure
function of the tree structure (stable across Python versions).
"""


def flatten(tree, prefix=""):
    """Flatten a nested dict of arrays -> list[(path, leaf)] sorted by key."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(flatten(tree[k], prefix + k + "."))
    else:
        out.append((prefix[:-1], tree))
    return out


def unflatten(paths, leaves):
    """Inverse of flatten given the same path list."""
    root = {}
    for path, leaf in zip(paths, leaves):
        parts = path.split(".")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root
