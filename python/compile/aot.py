"""AOT pipeline: lower every fused step to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
xla crate links xla_extension 0.5.1, which rejects jax>=0.5 protos with
64-bit instruction ids; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs under --out (default ../artifacts):
  <name>.hlo.txt          one per StepSpec
  init_<task>_<size>.bin  initial parameters, concatenated little-endian
                          f32 in manifest order (Rust reads shapes from
                          the manifest and slices)
  manifest.json           artifact index the Rust runtime is driven by
"""

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .config import MODEL_SIZES, OPTIMIZERS
from .pytree import flatten
from .train_step import (StepSpec, build_eval_step, build_logits_step,
                         build_train_step, init_example_params)

# Per-task batch sizes baked into the artifacts (paper: cls bsz 32,
# mt bsz 64, lm bsz 24 -- scaled to the CPU testbed, same ratios kept
# configurable here).
# Sized for the 1-core CPU testbed: tiny carries the sweep experiments
# (Figs. 2/3/5, Tables I/II) at ~25 ms/step; small carries the Fig. 4 /
# Table IV rows; base is the end-to-end example.
BATCH = {
    ("lm", "tiny"): 16, ("cls", "tiny"): 16, ("mt", "tiny"): 16,
    ("lm", "small"): 16, ("cls", "small"): 16, ("mt", "small"): 16,
    ("lm", "base"): 8,
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_spec(spec: StepSpec, out_dir: str) -> dict:
    """Lower one StepSpec to <name>.hlo.txt; return its manifest entry."""
    t0 = time.time()
    args = [jax.ShapeDtypeStruct(shape, dtype) for _, shape, dtype in spec.inputs]
    lowered = jax.jit(spec.fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, spec.name + ".hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    dt = time.time() - t0
    print(f"  {spec.name}: {len(text) / 1e6:.1f} MB HLO in {dt:.1f}s")
    return {
        "name": spec.name,
        "file": spec.name + ".hlo.txt",
        "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in spec.inputs],
        "outputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in spec.outputs],
        "param_table": [{"name": n, "shape": s, "offset": o} for n, s, o in spec.param_table],
        "state_table": [{"name": n, "shape": s, "offset": o} for n, s, o in spec.state_table],
        "meta": spec.meta,
    }


def dump_init(task: str, size: str, out_dir: str) -> dict:
    """Dump deterministic initial weights for (task-head, size)."""
    from .config import N_CLASSES
    cfg = MODEL_SIZES[size]
    n_classes = N_CLASSES if task == "cls" else 0
    params = init_example_params(cfg, n_classes)
    flat = flatten(params)
    name = f"init_{task}_{size}.bin"
    with open(os.path.join(out_dir, name), "wb") as f:
        for _, leaf in flat:
            f.write(np.asarray(leaf, np.float32).tobytes())
    return {
        "name": name,
        "params": [{"name": "param." + p, "shape": list(l.shape)} for p, l in flat],
    }


def build_all(out_dir: str, sizes=("tiny", "small", "base"), quick=False):
    os.makedirs(out_dir, exist_ok=True)
    artifacts, inits = [], []

    jobs = []  # (task, size, opts)
    for size in sizes:
        if size == "base":
            jobs.append(("lm", size))
        else:
            for task in ("lm", "cls", "mt"):
                jobs.append((task, size))

    for task, size in jobs:
        cfg = MODEL_SIZES[size]
        batch = BATCH[(task, size)]
        opts = ("alada",) if quick else OPTIMIZERS
        for opt in opts:
            artifacts.append(lower_spec(
                build_train_step(task, cfg, opt, batch), out_dir))
        artifacts.append(lower_spec(build_eval_step(task, cfg, batch), out_dir))
        if task == "mt":
            artifacts.append(lower_spec(build_logits_step(cfg, batch), out_dir))
        key = (task if task != "mt" else "lm", size)
        if not any(i["name"] == f"init_{key[0]}_{key[1]}.bin" for i in inits):
            inits.append(dump_init(key[0], size, out_dir))

    # Fig. 5 sensitivity sweep: beta-variant Alada artifacts for the mt
    # task (decay parameters are compile-time constants of the fused step,
    # so each (beta1, beta2) combination is its own artifact).
    if not quick:
        def tag(x):
            return str(x).replace(".", "p")
        for b1 in (0.0, 0.9):
            for b2 in (0.5, 0.9, 0.99, 0.999):
                cfg = MODEL_SIZES["tiny"]
                spec = build_train_step("mt", cfg, "alada", BATCH[("mt", "tiny")],
                                        beta1=b1, beta2=b2)
                spec.name = f"train_mt_tiny_alada_b1_{tag(b1)}_b2_{tag(b2)}"
                artifacts.append(lower_spec(spec, out_dir))

    manifest = {"version": 1, "artifacts": artifacts, "inits": inits}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(artifacts)} artifacts + {len(inits)} init dumps to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small,base")
    ap.add_argument("--quick", action="store_true",
                    help="alada-only (fast iteration)")
    args = ap.parse_args()
    build_all(args.out, sizes=tuple(args.sizes.split(",")), quick=args.quick)


if __name__ == "__main__":
    main()
