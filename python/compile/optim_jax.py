"""L2 in-graph optimizers: Alada, Adam, Adafactor over arbitrary pytrees.

Each optimizer exposes
    init(params)            -> state pytree
    update(grads, params, state, lr) -> (new_params, new_state)
and is pure, so the whole (model fwd/bwd + optimizer) composes into one
jitted train step that aot.py lowers to a single HLO artifact.

Matrix-shaped parameters route through the Pallas kernels (L1); vector /
scalar parameters (LayerNorm scales, biases) take the pure-jnp reference
path -- tiling a length-d vector is pointless and Adafactor/Alada both
degenerate gracefully there (the paper's Eq. 12 reshape maps a vector to
a 1 x n matrix, making p a scalar). Every parameter is first reshaped by
the balanced split rule (Eq. 12), which is a free view in row-major
layout.
"""

import jax.numpy as jnp

from .config import OptimConfig
from .kernels import adafactor as k_adafactor
from .kernels import adam as k_adam
from .kernels import alada as k_alada
from .kernels import ref
from .pytree import flatten, unflatten

# Parameters whose balanced split has min(m, n) below this use the jnp
# reference path instead of the Pallas kernels.
_MIN_TILE_DIM = 8


def _split(x):
    """Balanced-split view of a parameter (paper Eq. 12)."""
    m, n = ref.balanced_split(x.shape)
    return x.reshape(m, n), m, n


def _tree_map2(fn, a, b):
    fa, fb = flatten(a), flatten(b)
    leaves = [fn(x, y) for (_, x), (_, y) in zip(fa, fb)]
    return unflatten([p for p, _ in fa], leaves)


class Alada:
    """Paper Algorithm 2 over a pytree of parameters.

    Per-parameter state: first moment ``m`` (same shape — in a PyTorch
    deployment this lives in the grad slot, see paper Listing 1; here it
    is an explicit donated buffer and the memory model accounts it as the
    grad slot), factors ``p`` (m,), ``q`` (n,), and ``v0`` (1,). Global
    state: step counter ``t`` (1,) int32. Total overhead beyond the grad
    slot: O(m + n) per parameter.
    """

    def __init__(self, cfg: OptimConfig, use_pallas: bool = True):
        assert cfg.name == "alada"
        self.cfg = cfg
        self.use_pallas = use_pallas

    def init(self, params):
        slots = {}
        for path, x in flatten(params):
            xm, m, n = _split(x)
            slots[path.replace(".", "/")] = {
                "m": jnp.zeros_like(x),
                "p": jnp.zeros((m,), jnp.float32),
                "q": jnp.zeros((n,), jnp.float32),
                "v0": jnp.zeros((1,), jnp.float32),
            }
        return {"t": jnp.zeros((1,), jnp.int32), "slots": slots}

    def update(self, grads, params, state, lr):
        cfg = self.cfg
        t = state["t"][0]
        new_slots = {}
        new_params = {}
        flat_p = flatten(params)
        flat_g = dict(flatten(grads))
        for path, x in flat_p:
            g = flat_g[path]
            slot = state["slots"][path.replace(".", "/")]
            xm, m, n = _split(x)
            gm = g.reshape(m, n)
            # t == 0 initialisation (lines 8-12) — depends on G_0 only.
            v0_init, p_init, q_init = ref.alada_init_ref(gm)
            first = t == 0
            v0 = jnp.where(first, v0_init, slot["v0"][0])
            p = jnp.where(first, p_init, slot["p"])
            q = jnp.where(first, q_init, slot["q"])
            mm = slot["m"].reshape(m, n)
            use_kernel = self.use_pallas and min(m, n) >= _MIN_TILE_DIM
            step = k_alada.alada_matrix_step if use_kernel else ref.alada_step_ref
            x_new, m_new, p_new, q_new = step(
                xm, gm, mm, p, q, v0, t, cfg.beta1, cfg.beta2, cfg.eps, lr)
            key = path.replace(".", "/")
            new_slots[key] = {
                "m": m_new.reshape(x.shape),
                "p": p_new,
                "q": q_new,
                "v0": v0.reshape(1),
            }
            _set(new_params, path, x_new.reshape(x.shape))
        return new_params, {"t": state["t"] + 1, "slots": new_slots}


class Adam:
    """Adam with bias correction (paper Eq. 2-3); state 2x param size."""

    def __init__(self, cfg: OptimConfig, use_pallas: bool = True):
        assert cfg.name == "adam"
        self.cfg = cfg
        self.use_pallas = use_pallas

    def init(self, params):
        slots = {}
        for path, x in flatten(params):
            slots[path.replace(".", "/")] = {
                "m": jnp.zeros_like(x),
                "u": jnp.zeros_like(x),
            }
        return {"t": jnp.zeros((1,), jnp.int32), "slots": slots}

    def update(self, grads, params, state, lr):
        cfg = self.cfg
        t = state["t"][0]
        new_slots, new_params = {}, {}
        flat_g = dict(flatten(grads))
        for path, x in flatten(params):
            g = flat_g[path]
            slot = state["slots"][path.replace(".", "/")]
            xm, m, n = _split(x)
            use_kernel = self.use_pallas and min(m, n) >= _MIN_TILE_DIM
            step = k_adam.adam_matrix_step if use_kernel else ref.adam_step_ref
            x_new, m_new, u_new = step(
                xm, g.reshape(m, n), slot["m"].reshape(m, n),
                slot["u"].reshape(m, n), t, cfg.beta1, cfg.beta2, cfg.eps, lr)
            new_slots[path.replace(".", "/")] = {
                "m": m_new.reshape(x.shape),
                "u": u_new.reshape(x.shape),
            }
            _set(new_params, path, x_new.reshape(x.shape))
        return new_params, {"t": state["t"] + 1, "slots": new_slots}


class Adafactor:
    """Factored second moment, no first moment (paper SVI-A settings)."""

    def __init__(self, cfg: OptimConfig, use_pallas: bool = True):
        assert cfg.name == "adafactor"
        self.cfg = cfg
        self.use_pallas = use_pallas

    def init(self, params):
        slots = {}
        for path, x in flatten(params):
            xm, m, n = _split(x)
            slots[path.replace(".", "/")] = {
                "r": jnp.zeros((m,), jnp.float32),
                "c": jnp.zeros((n,), jnp.float32),
            }
        return {"t": jnp.zeros((1,), jnp.int32), "slots": slots}

    def update(self, grads, params, state, lr):
        cfg = self.cfg
        t = state["t"][0]
        new_slots, new_params = {}, {}
        flat_g = dict(flatten(grads))
        for path, x in flatten(params):
            g = flat_g[path]
            slot = state["slots"][path.replace(".", "/")]
            xm, m, n = _split(x)
            use_kernel = self.use_pallas and min(m, n) >= _MIN_TILE_DIM
            step = (k_adafactor.adafactor_matrix_step if use_kernel
                    else ref.adafactor_step_ref)
            x_new, r_new, c_new = step(
                xm, g.reshape(m, n), slot["r"], slot["c"],
                t, cfg.beta2, cfg.eps, lr)
            new_slots[path.replace(".", "/")] = {"r": r_new, "c": c_new}
            _set(new_params, path, x_new.reshape(x.shape))
        return new_params, {"t": state["t"] + 1, "slots": new_slots}


def _set(tree, path, leaf):
    parts = path.split(".")
    node = tree
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = leaf


def make_optimizer(name: str, use_pallas: bool = True,
                   beta1=None, beta2=None, eps=None):
    """Factory: optimizer by name with paper-default decay parameters."""
    cfg = OptimConfig.default(name)
    if beta1 is not None or beta2 is not None or eps is not None:
        cfg = OptimConfig(
            name,
            beta1=cfg.beta1 if beta1 is None else beta1,
            beta2=cfg.beta2 if beta2 is None else beta2,
            eps=cfg.eps if eps is None else eps,
        )
    klass = {"alada": Alada, "adam": Adam, "adafactor": Adafactor}[name]
    return klass(cfg, use_pallas=use_pallas)
