"""Fused train / eval step builders for the AOT pipeline.

Every artifact has a *flat-packed* signature: all f32 parameters are
concatenated into one ``params`` vector and all f32 optimizer-state
leaves into one ``opt_state`` vector (the int32 step counter travels
separately). Inside the jitted function the vectors are statically
sliced and reshaped per leaf -- free for XLA (bitcasts that fuse away) --
so the Rust runtime marshals 4-6 buffers per step instead of hundreds.
The exact leaf order/offset table goes into artifacts/manifest.json and
matches the init_*.bin dumps byte-for-byte.

Signatures
  train:  (params f32[P], opt_state f32[S], t i32[1], batch..., lr f32[1])
       -> (params', opt_state', t', loss f32[1])
  eval:   (params, batch...) -> task-specific metrics
  logits: (params, tokens)   -> full-sequence LM logits (greedy decode)
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .config import N_CLASSES, ModelConfig
from .optim_jax import make_optimizer
from .pytree import flatten, unflatten


@dataclass
class StepSpec:
    name: str
    inputs: list    # [(name, shape, dtype)]
    outputs: list   # [(name, shape, dtype)]
    meta: dict
    fn: object      # the flat-signature python callable
    param_table: list  # [(leaf_name, shape, offset)] into the params vector
    state_table: list  # [(leaf_name, shape, offset)] into the opt_state vector


class Packer:
    """Pack/unpack a pytree of f32 leaves into one flat vector."""

    def __init__(self, tree, skip=()):
        self.entries = []  # (path, shape, offset, size)
        ofs = 0
        for path, leaf in flatten(tree):
            if path in skip:
                continue
            size = int(np.prod(leaf.shape)) if leaf.shape else 1
            self.entries.append((path, tuple(leaf.shape), ofs, size))
            ofs += size
        self.total = ofs

    def pack(self, tree):
        flat = dict(flatten(tree))
        return jnp.concatenate(
            [flat[p].reshape(-1).astype(jnp.float32) for p, _, _, _ in self.entries])

    def unpack(self, vec):
        leaves, paths = [], []
        for path, shape, ofs, size in self.entries:
            leaves.append(vec[ofs:ofs + size].reshape(shape))
            paths.append(path)
        return unflatten(paths, leaves)

    def table(self):
        return [(p, list(s), o) for p, s, o, _ in self.entries]


def _sig(named):
    return [(n, tuple(s), d) for n, s, d in named]


def _batch_sig(task, batch, seq):
    if task == "lm":
        return [("batch.tokens", (batch, seq), "int32")]
    if task == "mt":
        return [("batch.tokens", (batch, seq), "int32"),
                ("batch.loss_mask", (batch, seq), "float32")]
    if task == "cls":
        return [("batch.tokens", (batch, seq), "int32"),
                ("batch.labels", (batch,), "int32")]
    raise ValueError(task)


def _loss_fn(task, cfg):
    if task == "lm":
        return lambda params, tokens: M.lm_loss(params, tokens, cfg)[0]
    if task == "mt":
        return lambda params, tokens, mask: M.mt_loss(params, tokens, mask, cfg)[0]
    if task == "cls":
        return lambda params, tokens, labels: M.cls_loss(params, tokens, labels, cfg)[0]
    raise ValueError(task)


def init_example_params(cfg: ModelConfig, n_classes: int):
    """Deterministic parameter skeleton (seed 0): shapes for lowering AND
    the runtime's initial weights (dumped to artifacts/init_*.bin)."""
    return M.init_params(cfg, jax.random.PRNGKey(0), n_classes)


def build_train_step(task: str, cfg: ModelConfig, opt_name: str,
                     batch: int, use_pallas: bool = True,
                     beta1=None, beta2=None) -> StepSpec:
    opt = make_optimizer(opt_name, use_pallas=use_pallas, beta1=beta1, beta2=beta2)
    n_classes = N_CLASSES if task == "cls" else 0
    params0 = init_example_params(cfg, n_classes)
    state0 = opt.init(params0)
    loss_fn = _loss_fn(task, cfg)

    p_pack = Packer(params0)
    s_pack = Packer(state0, skip=("t",))

    def step_flat(params_vec, state_vec, t, *rest):
        batch_args, lr = rest[:-1], rest[-1][0]
        params = p_pack.unpack(params_vec)
        state = s_pack.unpack(state_vec)
        state["t"] = t
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch_args)
        new_params, new_state = opt.update(grads, params, state, lr)
        t_new = new_state.pop("t")
        return (p_pack.pack(new_params), s_pack.pack(new_state),
                t_new, loss.reshape(1))

    bsig = _batch_sig(task, batch, cfg.max_seq)
    inputs = ([("params", (p_pack.total,), "float32"),
               ("opt_state", (s_pack.total,), "float32"),
               ("t", (1,), "int32")] + bsig + [("lr", (1,), "float32")])
    outputs = [("params", (p_pack.total,), "float32"),
               ("opt_state", (s_pack.total,), "float32"),
               ("t", (1,), "int32"),
               ("loss", (1,), "float32")]
    name = f"train_{task}_{cfg.name}_{opt_name}"
    meta = {"kind": "train", "task": task, "size": cfg.name, "opt": opt_name,
            "batch": batch, "seq": cfg.max_seq, "vocab": cfg.vocab,
            "param_elems": p_pack.total, "state_elems": s_pack.total,
            "param_count": cfg.param_count(n_classes)}
    return StepSpec(name, _sig(inputs), _sig(outputs), meta, step_flat,
                    p_pack.table(), s_pack.table())


def build_eval_step(task: str, cfg: ModelConfig, batch: int) -> StepSpec:
    n_classes = N_CLASSES if task == "cls" else 0
    params0 = init_example_params(cfg, n_classes)
    p_pack = Packer(params0)

    if task == "cls":
        def eval_flat(params_vec, tokens, labels):
            params = p_pack.unpack(params_vec)
            logits = M.cls_logits(params, tokens, cfg)
            _, total, count = M.cls_loss(params, tokens, labels, cfg)
            return (jnp.argmax(logits, axis=-1).astype(jnp.int32),
                    total.reshape(1), count.reshape(1))
        outputs = [("pred", (batch,), "int32"), ("sum_nll", (1,), "float32"),
                   ("count", (1,), "float32")]
    else:
        def eval_flat(params_vec, *batch_args):
            params = p_pack.unpack(params_vec)
            if task == "lm":
                _, total, count = M.lm_loss(params, batch_args[0], cfg)
            else:
                _, total, count = M.mt_loss(params, batch_args[0], batch_args[1], cfg)
            return (total.reshape(1), count.reshape(1))
        outputs = [("sum_nll", (1,), "float32"), ("count", (1,), "float32")]

    inputs = [("params", (p_pack.total,), "float32")] + _batch_sig(task, batch, cfg.max_seq)
    name = f"eval_{task}_{cfg.name}"
    meta = {"kind": "eval", "task": task, "size": cfg.name, "batch": batch,
            "seq": cfg.max_seq, "vocab": cfg.vocab, "param_elems": p_pack.total}
    return StepSpec(name, _sig(inputs), _sig(outputs), meta, eval_flat,
                    p_pack.table(), [])


def build_logits_step(cfg: ModelConfig, batch: int) -> StepSpec:
    params0 = init_example_params(cfg, 0)
    p_pack = Packer(params0)

    def logits_flat(params_vec, tokens):
        return (M.lm_logits(p_pack.unpack(params_vec), tokens, cfg),)

    inputs = [("params", (p_pack.total,), "float32"),
              ("batch.tokens", (batch, cfg.max_seq), "int32")]
    outputs = [("logits", (batch, cfg.max_seq, cfg.vocab), "float32")]
    name = f"logits_lm_{cfg.name}"
    meta = {"kind": "logits", "task": "lm", "size": cfg.name, "batch": batch,
            "seq": cfg.max_seq, "vocab": cfg.vocab, "param_elems": p_pack.total}
    return StepSpec(name, _sig(inputs), _sig(outputs), meta, logits_flat,
                    p_pack.table(), [])
