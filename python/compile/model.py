"""L2: from-scratch decoder-only transformer in pure JAX.

One parameter tree + one forward covers the paper's three task families:

* ``lm``  -- next-token language modelling (Fig. 4 / Table III proxy).
* ``cls`` -- sequence classification via masked mean-pool head
             (Fig. 2 / Table I proxy for the GLUE fine-tuning runs).
* ``mt``  -- prefix-LM translation: the batch carries a loss mask that
             restricts the next-token loss to target positions
             (Fig. 3 / Table II proxy for the T5 runs). A prefix LM
             rather than a full encoder-decoder keeps a single model
             code path; the optimizer comparison the paper makes is
             architecture-agnostic (see DESIGN.md substitutions).

No flax/haiku: parameters are nested dicts, init/forward are plain
functions, so the AOT pipeline controls flattening order exactly.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig

PAD_ID = 0  # token 0 is reserved as padding everywhere in the repo


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key, n_classes: int = 0):
    """GPT-2-style init: N(0, 0.02), residual projections scaled by depth."""
    std = 0.02
    res_std = std / jnp.sqrt(2.0 * cfg.n_layers)
    d, f = cfg.d_model, cfg.d_ff
    keys = iter(jax.random.split(key, 6 * cfg.n_layers + 4))

    def norm(shape, s):
        return (jax.random.normal(next(keys), shape) * s).astype(jnp.float32)

    params = {
        "tok_emb": norm((cfg.vocab, d), std),
        "pos_emb": norm((cfg.max_seq, d), std),
        "ln_f": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
    }
    for l in range(cfg.n_layers):
        params[f"layer_{l:02d}"] = {
            "ln1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "attn": {
                "wq": norm((d, d), std),
                "wk": norm((d, d), std),
                "wv": norm((d, d), std),
                "wo": norm((d, d), res_std),
            },
            "ln2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
            "mlp": {
                "w1": norm((d, f), std),
                "b1": jnp.zeros((f,)),
                "w2": norm((f, d), res_std),
                "b2": jnp.zeros((d,)),
            },
        }
    if n_classes:
        params["head"] = {
            "w": norm((d, n_classes), std),
            "b": jnp.zeros((n_classes,)),
        }
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(p, x, cfg: ModelConfig):
    b, l, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(w):
        return (x @ w).reshape(b, l, h, hd).transpose(0, 2, 1, 3)

    q, k, v = split(p["wq"]), split(p["wk"]), split(p["wv"])
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    causal = jnp.tril(jnp.ones((l, l), bool))
    att = jnp.where(causal[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, l, d)
    return out @ p["wo"]


def _mlp(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def forward(params, tokens, cfg: ModelConfig):
    """Token ids (B, L) -> final hidden states (B, L, d)."""
    b, l = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:l][None]
    for i in range(cfg.n_layers):
        p = params[f"layer_{i:02d}"]
        x = x + _attention(p["attn"], _layer_norm(x, p["ln1"]["scale"], p["ln1"]["bias"]), cfg)
        x = x + _mlp(p["mlp"], _layer_norm(x, p["ln2"]["scale"], p["ln2"]["bias"]))
    return _layer_norm(x, params["ln_f"]["scale"], params["ln_f"]["bias"])


def lm_logits(params, tokens, cfg: ModelConfig):
    """Tied-embedding next-token logits (B, L, vocab)."""
    return forward(params, tokens, cfg) @ params["tok_emb"].T


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _token_nll(logits, targets, mask):
    """Masked mean next-token NLL; returns (mean_nll, sum_nll, count)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    total, count = jnp.sum(nll), jnp.sum(mask)
    return total / jnp.maximum(count, 1.0), total, count


def lm_loss(params, tokens, cfg: ModelConfig):
    """Shifted next-token loss over non-pad positions."""
    logits = lm_logits(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    mask = (targets != PAD_ID).astype(jnp.float32)
    return _token_nll(logits, targets, mask)


def mt_loss(params, tokens, loss_mask, cfg: ModelConfig):
    """Prefix-LM loss: next-token NLL restricted to target positions."""
    logits = lm_logits(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    mask = loss_mask[:, 1:] * (targets != PAD_ID).astype(jnp.float32)
    return _token_nll(logits, targets, mask)


def cls_logits(params, tokens, cfg: ModelConfig):
    """Masked mean-pool over non-pad positions -> linear head."""
    h = forward(params, tokens, cfg)
    mask = (tokens != PAD_ID).astype(jnp.float32)[..., None]
    pooled = jnp.sum(h * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1.0)
    return pooled @ params["head"]["w"] + params["head"]["b"]


def cls_loss(params, tokens, labels, cfg: ModelConfig):
    logits = cls_logits(params, tokens, cfg)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    n = logits.shape[0]
    return jnp.mean(logz - gold), jnp.sum(logz - gold), jnp.float32(n)
