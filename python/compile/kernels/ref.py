"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Each function performs one optimizer step on a single matrix parameter,
written to match the paper line-by-line (Algorithm 2 for Alada). The
pytest suite checks the Pallas kernels against these under hypothesis
shape/dtype sweeps; they are also the fallback path used for small /
vector parameters where tiling is pointless.

All functions are functional: they take the current state and return the
updated state, never mutating in place.
"""

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Alada (paper Algorithm 2)
# ---------------------------------------------------------------------------

def alada_moment_ref(g, m, beta1, t):
    """Lines 5-7: EMA first moment, bias correction, squared momentum.

    Returns (m_new, m_hat). V = m_hat**2 is computed on demand by callers
    (never materialised by the Pallas path -- see kernels/alada.py).
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    m_hat = m_new / (1.0 - beta1 ** (t + 1))
    return m_new, m_hat


def alada_factor_ref(m_hat, p, q, beta2, t, eps):
    """Lines 13-19: alternating rank-one factor update.

    t even -> update p (project V onto q); t odd -> update q.
    Returns (p_new, q_new).
    """
    v = m_hat * m_hat
    p_star = v @ q / (jnp.sum(q * q) + eps)
    q_star = v.T @ p / (jnp.sum(p * p) + eps)
    even = (t % 2) == 0
    p_new = jnp.where(even, beta2 * p + (1.0 - beta2) * p_star, p)
    q_new = jnp.where(even, q, beta2 * q + (1.0 - beta2) * q_star)
    return p_new, q_new


def alada_descent_ref(x, m_hat, p, q, v0, beta2, t, eps, lr):
    """Lines 20-22: reconstruct U = p q^T, bias-correct, descend.

    The rank-one product is formed lazily tile-by-tile in the Pallas
    kernel; here we materialise it for clarity. U - beta2^{t+1} v0 is
    mathematically >= 0 (induction over the EMA); we clamp at 0 to guard
    against floating-point dips before the sqrt.
    """
    bc2 = beta2 ** (t + 1)
    u = p[:, None] * q[None, :]
    u_hat = jnp.maximum(u - bc2 * v0, 0.0) / (1.0 - bc2)
    return x - lr * m_hat / jnp.sqrt(u_hat + eps)


def alada_init_ref(g):
    """Lines 8-12: v0 = ||G0||^2 / (m n); p0 = sqrt(v0) 1_m, q0 = sqrt(v0) 1_n."""
    m, n = g.shape
    v0 = jnp.sum(g * g) / (m * n)
    root = jnp.sqrt(v0)
    return v0, jnp.full((m,), root, g.dtype), jnp.full((n,), root, g.dtype)


def alada_step_ref(x, g, m, p, q, v0, t, beta1, beta2, eps, lr):
    """One full Alada step on a matrix parameter (Algorithm 2 body).

    `v0`, `p`, `q` must already be initialised (the t = 0 initialisation
    is the caller's job because it depends on G_0 only).
    Returns (x_new, m_new, p_new, q_new).
    """
    m_new, m_hat = alada_moment_ref(g, m, beta1, t)
    p_new, q_new = alada_factor_ref(m_hat, p, q, beta2, t, eps)
    x_new = alada_descent_ref(x, m_hat, p_new, q_new, v0, beta2, t, eps, lr)
    return x_new, m_new, p_new, q_new


# ---------------------------------------------------------------------------
# Adam (Kingma & Ba 2015; paper Eq. (2)-(3))
# ---------------------------------------------------------------------------

def adam_step_ref(x, g, m, u, t, beta1, beta2, eps, lr):
    """One Adam step with bias correction. Returns (x_new, m_new, u_new)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    u_new = beta2 * u + (1.0 - beta2) * g * g
    m_hat = m_new / (1.0 - beta1 ** (t + 1))
    u_hat = u_new / (1.0 - beta2 ** (t + 1))
    x_new = x - lr * m_hat / (jnp.sqrt(u_hat) + eps)
    return x_new, m_new, u_new


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), first moment disabled as in the paper
# ---------------------------------------------------------------------------

def adafactor_step_ref(x, g, r, c, t, beta2, eps, lr):
    """One factored-second-moment step on a matrix parameter.

    r: row accumulator (m,), c: column accumulator (n,). The second moment
    is reconstructed as rec(r, c) = r c^T / mean(r). Update clipping and
    relative step sizes from the full Adafactor recipe are intentionally
    omitted: the paper runs Adafactor with a fixed external schedule and
    first moment disabled (SVI-A).
    """
    v = g * g + eps
    r_new = beta2 * r + (1.0 - beta2) * jnp.mean(v, axis=1)
    c_new = beta2 * c + (1.0 - beta2) * jnp.mean(v, axis=0)
    bc = 1.0 - beta2 ** (t + 1)
    r_hat, c_hat = r_new / bc, c_new / bc
    u = r_hat[:, None] * c_hat[None, :] / jnp.mean(r_hat)
    x_new = x - lr * g / (jnp.sqrt(u) + eps)
    return x_new, r_new, c_new


# ---------------------------------------------------------------------------
# Shared helper: the paper's tensor reshaping rule (Eq. 12)
# ---------------------------------------------------------------------------

def balanced_split(shape):
    """Return (m, n) minimising |prod(k[:j]) - prod(k[j:])| over j (Eq. 12).

    Vectors (tau = 1) resolve to (1, k); scalars to (1, 1). The split is a
    pure view: reshaping in row-major order never copies.
    """
    dims = list(shape) if shape else [1]
    total = 1
    for k in dims:
        total *= k
    best_j, best_gap = 0, None
    left = 1
    for j in range(len(dims) + 1):
        right = total // left if left else total
        gap = abs(left - right)
        if best_gap is None or gap < best_gap:
            best_gap, best_j = gap, j
        if j < len(dims):
            left *= dims[j]
    m = 1
    for k in dims[:best_j]:
        m *= k
    return m, total // m
