"""L1: Pallas kernels for the optimizer hot-spots + pure-jnp oracles."""

from . import adafactor, adam, alada, common, ref  # noqa: F401
