"""Pallas kernels for the Alada update (paper Algorithm 2).

The update is split into three streaming kernels so that neither the
squared momentum V = M_hat^2 nor the reconstructed second moment
U = p q^T is ever materialised in HBM -- the paper's memory argument,
expressed as a tiling schedule:

  1. ``moment``  -- elementwise EMA + bias correction (lines 5-6).
     Emits M_{t+1} and M_hat; V is recomputed on the fly downstream.
  2. ``factor``  -- one pass over M_hat per row-block computing BOTH
     projection candidates (lines 14 / 18): p* rows V q and the
     cross-block accumulation of q* = V^T p. The parity selection and
     the cheap O(m + n) EMA glue happen in jnp outside the kernel.
  3. ``descent`` -- line 22. Each VMEM tile reconstructs its p_i q_j
     patch in-register (rank-one outer product), applies the
     bias-correction (line 21) and the step, so U never exists in HBM.

GPU->TPU adaptation: the CUDA implementation would broadcast p/q from
shared memory per threadblock; here BlockSpec streams full-width row
blocks HBM->VMEM and the outer product is free vector work on the VPU.
No MXU use -- the kernels are bandwidth-bound (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_rows, row_block, scalar


# ---------------------------------------------------------------------------
# kernel 1: first-moment EMA + bias correction
# ---------------------------------------------------------------------------

def _moment_kernel(beta1, g_ref, m_ref, bc1_ref, m_new_ref, m_hat_ref):
    g = g_ref[...]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    m_new_ref[...] = m_new
    # bc1 = 1 / (1 - beta1^{t+1})
    m_hat_ref[...] = m_new * bc1_ref[0, 0]


def moment(g, m, beta1, bc1):
    """EMA + bias-correct the first moment. Returns (m_new, m_hat)."""
    mm, nn = g.shape
    bm = row_block(mm, nn)
    grid = (grid_rows(mm, bm),)
    blk = pl.BlockSpec((bm, nn), lambda i: (i, 0))
    sblk = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_moment_kernel, beta1),
        grid=grid,
        in_specs=[blk, blk, sblk],
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct(g.shape, g.dtype)] * 2,
        interpret=True,
    )(g, m, scalar(bc1))


# ---------------------------------------------------------------------------
# kernel 2: both rank-one projection candidates in one pass over M_hat
# ---------------------------------------------------------------------------

def _factor_kernel(g_ref_unused, m_hat_ref, p_ref, q_ref, p_star_ref, q_acc_ref):
    i = pl.program_id(0)
    m_hat = m_hat_ref[...]
    v = m_hat * m_hat  # V recomputed in-register; never stored to HBM
    # p* candidate for this row block: V q
    p_star_ref[...] = v @ q_ref[...]
    # q* accumulator: V^T p, reduced across row blocks (grid is sequential)
    @pl.when(i == 0)
    def _init():
        q_acc_ref[...] = jnp.zeros_like(q_acc_ref)
    q_acc_ref[...] += v.T @ p_ref[...]


def factor_candidates(m_hat, p, q):
    """One streaming pass computing (V q, V^T p) without materialising V.

    Zero-padding of ragged row blocks is safe: padded rows contribute 0
    to the q accumulator and their p* lanes are masked on store.
    """
    mm, nn = m_hat.shape
    bm = row_block(mm, nn)
    grid = (grid_rows(mm, bm),)
    return pl.pallas_call(
        functools.partial(_factor_kernel, None),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, nn), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((nn,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((nn,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm,), m_hat.dtype),
            jax.ShapeDtypeStruct((nn,), m_hat.dtype),
        ],
        interpret=True,
    )(m_hat, p, q)


# ---------------------------------------------------------------------------
# kernel 3: descent with lazy rank-one reconstruction
# ---------------------------------------------------------------------------

def _descent_kernel(eps, x_ref, m_hat_ref, p_ref, q_ref, s_ref, x_new_ref):
    # s = [lr, beta2^{t+1} * v0, 1/(1 - beta2^{t+1})]
    lr, bc2v0, inv = s_ref[0, 0], s_ref[0, 1], s_ref[0, 2]
    u = p_ref[...][:, None] * q_ref[...][None, :]  # in-register outer product
    u_hat = jnp.maximum(u - bc2v0, 0.0) * inv
    x_new_ref[...] = x_ref[...] - lr * m_hat_ref[...] / jnp.sqrt(u_hat + eps)


def descent(x, m_hat, p, q, v0, beta2, t, eps, lr):
    """Line 20-22: X - lr * M_hat / sqrt(U_hat + eps), U built per-tile."""
    mm, nn = x.shape
    bm = row_block(mm, nn)
    grid = (grid_rows(mm, bm),)
    bc2 = beta2 ** (t + 1.0)
    s = jnp.stack([
        jnp.asarray(lr, jnp.float32),
        (bc2 * v0).astype(jnp.float32),
        (1.0 / (1.0 - bc2)).astype(jnp.float32),
    ]).reshape(1, 3)
    blk = pl.BlockSpec((bm, nn), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_descent_kernel, eps),
        grid=grid,
        in_specs=[
            blk,
            blk,
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((nn,), lambda i: (0,)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, m_hat, p, q, s)


# ---------------------------------------------------------------------------
# glue: one full Alada step on a matrix parameter
# ---------------------------------------------------------------------------

def alada_matrix_step(x, g, m, p, q, v0, t, beta1, beta2, eps, lr):
    """Pallas-path Alada step; same contract as ref.alada_step_ref.

    `t` is a traced int32 scalar (part of the optimizer state), so parity
    selection uses jnp.where over both candidates -- both are produced by
    the single factor pass anyway.
    """
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    bc1 = 1.0 / (1.0 - beta1 ** (tf + 1.0))
    m_new, m_hat = moment(g, m, beta1, bc1)
    p_star_num, q_star_num = factor_candidates(m_hat, p, q)
    p_star = p_star_num / (jnp.sum(q * q) + eps)
    q_star = q_star_num / (jnp.sum(p * p) + eps)
    even = (t % 2) == 0
    p_new = jnp.where(even, beta2 * p + (1.0 - beta2) * p_star, p)
    q_new = jnp.where(even, q, beta2 * q + (1.0 - beta2) * q_star)
    x_new = descent(x, m_hat, p_new, q_new, v0, beta2, tf, eps, lr)
    return x_new, m_new, p_new, q_new
