"""Shared plumbing for the Pallas L1 kernels.

All kernels are lowered with ``interpret=True``: the CPU PJRT client used
by the Rust runtime cannot execute Mosaic custom-calls, so interpret mode
is the correctness path; real-TPU performance is estimated analytically
in EXPERIMENTS.md SPerf from the VMEM footprints declared here.

Tiling convention: optimizer updates are memory-bound elementwise /
rank-one ops, so we tile the *row* dimension only and stream full-width
blocks HBM->VMEM. ``row_block`` picks the largest block that (a) fits a
VMEM budget alongside its vector slivers and (b) keeps the grid small.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget per resident operand tile, in f32 elements. 256 KiB/tile
# leaves room for ~8 resident tiles + double buffering inside a 16 MiB
# TPU VMEM. On CPU-interpret this only shapes the grid.
_VMEM_TILE_ELEMS = 64 * 1024


def row_block(m: int, n: int) -> int:
    """Pick the row-block size for an (m, n) matrix kernel."""
    if m * n <= _VMEM_TILE_ELEMS:
        return m  # single block
    bm = max(1, _VMEM_TILE_ELEMS // max(n, 1))
    bm = min(bm, m)
    # round down to a multiple of 8 (sublane) when possible
    if bm >= 8:
        bm -= bm % 8
    return bm


def grid_rows(m: int, bm: int) -> int:
    return (m + bm - 1) // bm


def scalar(x, dtype=jnp.float32):
    """Wrap a scalar into the (1, 1) array Pallas SMEM-style operands use."""
    return jnp.asarray(x, dtype).reshape(1, 1)


def vmem_footprint_bytes(m: int, n: int, n_mats: int, n_vecs: int) -> int:
    """Analytic VMEM footprint of one grid step: ``n_mats`` row-block
    matrix tiles plus ``n_vecs`` full-width vector slivers (f32)."""
    bm = row_block(m, n)
    return 4 * (n_mats * bm * n + n_vecs * (bm + n))
