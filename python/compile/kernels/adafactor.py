"""Adafactor update as Pallas kernels (baseline; paper SVI-A variant).

Two streaming passes mirroring the Alada kernels: one accumulation pass
producing row/column statistics of V = G^2 + eps, and one descent pass
reconstructing rec(r, c) = r c^T / mean(r) tile-by-tile. First moment is
disabled and the external step-size schedule is used, exactly as the
paper configures Adafactor.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_rows, row_block


def _stats_kernel(eps, g_ref, r_ref, c_acc_ref):
    i = pl.program_id(0)
    g = g_ref[...]
    v = g * g + eps
    r_ref[...] = jnp.sum(v, axis=1)
    @pl.when(i == 0)
    def _init():
        c_acc_ref[...] = jnp.zeros_like(c_acc_ref)
    c_acc_ref[...] += jnp.sum(v, axis=0)


def _descent_kernel(eps, x_ref, g_ref, r_ref, c_ref, s_ref, x_new_ref):
    # s = [lr, 1/mean(r_hat)]
    lr, inv_mean = s_ref[0, 0], s_ref[0, 1]
    u = r_ref[...][:, None] * c_ref[...][None, :] * inv_mean
    x_new_ref[...] = x_ref[...] - lr * g_ref[...] / (jnp.sqrt(u) + eps)


def adafactor_matrix_step(x, g, r, c, t, beta2, eps, lr):
    """One Adafactor step; same contract as ref.adafactor_step_ref."""
    mm, nn = x.shape
    bm = row_block(mm, nn)
    grid = (grid_rows(mm, bm),)
    blk = pl.BlockSpec((bm, nn), lambda i: (i, 0))

    row_sum, col_sum = pl.pallas_call(
        functools.partial(_stats_kernel, eps),
        grid=grid,
        in_specs=[blk],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((nn,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm,), g.dtype),
            jax.ShapeDtypeStruct((nn,), g.dtype),
        ],
        interpret=True,
    )(g)

    r_new = beta2 * r + (1.0 - beta2) * row_sum / nn
    c_new = beta2 * c + (1.0 - beta2) * col_sum / mm
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    bc = 1.0 - beta2 ** (tf + 1.0)
    r_hat, c_hat = r_new / bc, c_new / bc
    s = jnp.stack([
        jnp.asarray(lr, jnp.float32),
        1.0 / jnp.mean(r_hat),
    ]).reshape(1, 2)

    x_new = pl.pallas_call(
        functools.partial(_descent_kernel, eps),
        grid=grid,
        in_specs=[
            blk, blk,
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((nn,), lambda i: (0,)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, g, r_hat, c_hat, s)
    return x_new, r_new, c_new
