"""Fused Adam update as a single Pallas kernel (baseline for Table IV).

Adam is purely elementwise, so one streaming kernel updates both momenta
and the parameter in a single HBM pass per tile -- the fair comparison
point for the per-step wall-clock column of Table IV.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import grid_rows, row_block


def _adam_kernel(beta1, beta2, eps, x_ref, g_ref, m_ref, u_ref, s_ref,
                 x_new_ref, m_new_ref, u_new_ref):
    # s = [lr, 1/(1-beta1^{t+1}), 1/(1-beta2^{t+1})]
    lr, bc1, bc2 = s_ref[0, 0], s_ref[0, 1], s_ref[0, 2]
    g = g_ref[...]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    u_new = beta2 * u_ref[...] + (1.0 - beta2) * g * g
    m_new_ref[...] = m_new
    u_new_ref[...] = u_new
    x_new_ref[...] = x_ref[...] - lr * (m_new * bc1) / (jnp.sqrt(u_new * bc2) + eps)


def adam_matrix_step(x, g, m, u, t, beta1, beta2, eps, lr):
    """One fused Adam step; same contract as ref.adam_step_ref."""
    mm, nn = x.shape
    bm = row_block(mm, nn)
    grid = (grid_rows(mm, bm),)
    tf = t.astype(jnp.float32) if hasattr(t, "astype") else jnp.float32(t)
    s = jnp.stack([
        jnp.asarray(lr, jnp.float32),
        1.0 / (1.0 - beta1 ** (tf + 1.0)),
        1.0 / (1.0 - beta2 ** (tf + 1.0)),
    ]).reshape(1, 3)
    blk = pl.BlockSpec((bm, nn), lambda i: (i, 0))
    sblk = pl.BlockSpec((1, 3), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_adam_kernel, beta1, beta2, eps),
        grid=grid,
        in_specs=[blk, blk, blk, blk, sblk],
        out_specs=[blk, blk, blk],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype)] * 3,
        interpret=True,
    )(x, g, m, u, s)
