//! Memory planner: "will this training run fit my GPU?"
//!
//! The practical question Alada answers (paper §I, Table IV) as a tool:
//! given a model shape, optimizer, and batch size, print the peak-memory
//! breakdown and the largest batch each optimizer supports on an
//! A800-class device. No artifacts needed — this runs the pure analytic
//! model.
//!
//! ```sh
//! cargo run --release --example memory_planner -- [--model gpt2-xl]
//! ```

use alada::cli::Args;
use alada::train::memory::{
    breakdown, fits_a800, ModelShape, A800_BYTES, GPT2_SMALL, GPT2_XL, T5_SMALL,
};

const OPTS: [&str; 6] = ["sgd", "adam", "adafactor", "alada", "came", "sm3"];

fn max_batch(model: ModelShape, opt: &str) -> usize {
    let mut batch = 0;
    while batch < 512 && fits_a800(model, opt, batch + 1, model.max_seq) {
        batch += 1;
    }
    batch
}

fn main() {
    let args = Args::from_env();
    let models: Vec<ModelShape> = match args.flag("model") {
        Some("gpt2-small") => vec![GPT2_SMALL],
        Some("gpt2-xl") => vec![GPT2_XL],
        Some("t5-small") => vec![T5_SMALL],
        _ => vec![GPT2_SMALL, GPT2_XL, T5_SMALL],
    };

    for model in models {
        println!(
            "\n=== {} ({:.1}M params, seq {}) on an 80 GB A800 ===",
            model.name,
            model.param_count() as f64 / 1e6,
            model.max_seq
        );
        println!(
            "{:<11}{:>14}{:>16}{:>18}",
            "optimizer", "state (GB)", "bsz-1 peak (GB)", "max batch (A800)"
        );
        for opt in OPTS {
            let b = breakdown(model, opt, 1, model.max_seq);
            println!(
                "{:<11}{:>14.3}{:>16.2}{:>18}",
                opt,
                b.opt_state as f64 / 1e9,
                b.total_gb(),
                max_batch(model, opt)
            );
        }
        // the paper's headline: the batch-size gap Alada opens vs Adam
        let adam = max_batch(model, "adam");
        let alada = max_batch(model, "alada");
        println!(
            "--> Alada trains at {:.1}× Adam's max batch on this model (capacity {} GB)",
            alada as f64 / adam.max(1) as f64,
            A800_BYTES / 1_000_000_000
        );
    }
}
