//! Fine-tuning sweep: the coordinator as a user-facing tool.
//!
//! The workload the paper's intro motivates — fine-tune one model on a
//! suite of understanding tasks under several optimizers and pick the
//! winner — expressed directly against the coordinator API: build a job
//! grid, fan it out over workers, aggregate.
//!
//! ```sh
//! cargo run --release --example finetune_sweep -- [--steps N] [--workers N]
//! ```

use alada::cli::Args;
use alada::coordinator::job::{JobGrid, JobSpec};
use alada::coordinator::{default_workers, run_jobs};
use alada::data::CLS_TASKS;
use alada::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    alada::util::log::level_from_env();
    let args = Args::from_env();
    let steps = args.usize_or("steps", 120);
    let workers = args.usize_or("workers", default_workers());

    // 3 tasks × 3 optimizers × 2 learning rates, evaluated on test sets
    let mut grid = JobGrid::new();
    for (ti, task) in CLS_TASKS.iter().enumerate().take(3) {
        for opt in ["adam", "adafactor", "alada"] {
            for lr in [1e-3f32, 2e-3] {
                grid.push(
                    format!("sweep/{}/{}/lr{:.0e}", task.name, opt, lr),
                    JobSpec {
                        task: "cls".into(),
                        size: "tiny".into(),
                        artifact: None,
                        opt: opt.into(),
                        dataset: ti,
                        lr,
                        steps,
                        seed: 1,
                        record_every: steps,
                        eval: "cls".into(),
                    },
                );
            }
        }
    }
    println!("sweep: {} jobs on {workers} workers", grid.len());
    let results = run_jobs("artifacts", grid.into_jobs(), workers)?;

    let mut w = CsvWriter::create(
        "results/finetune_sweep.csv",
        &["task", "optimizer", "lr", "final_loss", "accuracy", "task_metric"],
    )?;
    println!(
        "\n{:<8}{:<11}{:>8}{:>12}{:>10}{:>13}",
        "task", "optimizer", "lr", "final loss", "acc", "task metric"
    );
    for r in &results {
        if let Some(err) = &r.error {
            println!("{:<40} FAILED: {err}", r.label);
            continue;
        }
        let task = CLS_TASKS[r.spec.dataset].name;
        let acc = r.metric("acc").unwrap_or(f64::NAN);
        let tm = r.metric("task_metric").unwrap_or(f64::NAN);
        w.row(&[
            task.to_string(),
            r.spec.opt.clone(),
            format!("{:.0e}", r.spec.lr),
            format!("{:.4}", r.final_cum_loss),
            format!("{acc:.4}"),
            format!("{tm:.2}"),
        ])?;
        println!(
            "{:<8}{:<11}{:>8}{:>12.4}{:>10.3}{:>13.2}",
            task,
            r.spec.opt,
            format!("{:.0e}", r.spec.lr),
            r.final_cum_loss,
            acc,
            tm
        );
    }
    w.flush()?;
    println!("\nwrote results/finetune_sweep.csv");
    Ok(())
}
