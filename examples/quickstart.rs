//! Quickstart: train a small Alada LM on the synthetic corpus.
//!
//! The 60-second tour of the public API: open the runtime, build a
//! training session from an AOT artifact, stream batches, watch the loss
//! fall, evaluate perplexity, save a checkpoint.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use alada::data::MarkovCorpus;
use alada::optim::Schedule;
use alada::runtime::executor::{BatchExtra, EvalSession};
use alada::runtime::{Runtime, TrainSession};
use alada::train::{checkpoint, metrics, TaskData, Trainer};

fn main() -> anyhow::Result<()> {
    alada::util::log::level_from_env();

    // 1. runtime + session: the artifact carries the fused (fwd + bwd +
    //    Alada update) step; Python is not involved at runtime.
    let rt = Runtime::open("artifacts")?;
    let sess = TrainSession::new(&rt, "lm", "tiny", "alada")?;
    println!(
        "model: {} params ({} KiB), optimizer state {} KiB",
        sess.params.len(),
        sess.param_bytes() / 1024,
        sess.opt_state_bytes() / 1024
    );

    // 2. data: a Markov-chain corpus with learnable structure.
    let corpus = MarkovCorpus::generate(256, 4, 60_000, 42);
    println!(
        "corpus: {} train tokens, entropy-rate floor ppl ≈ {:.1}",
        corpus.train.len(),
        corpus.entropy_rate.exp()
    );
    let (batch, seq) = (sess.batch, sess.seq);
    let data = TaskData::lm(corpus, batch, seq, 42);

    // 3. train 300 steps with the paper's diminishing schedule.
    let steps = 300;
    let mut trainer = Trainer::new(sess, data, Schedule::Diminishing { eta0: 8e-3, total: steps });
    trainer.record_every = 25;
    let out = trainer.run(steps)?;
    for (step, loss, avg) in &out.curve {
        println!("step {step:>4}  loss {loss:.4}  cum-avg {avg:.4}");
    }
    println!(
        "{} steps in {:.1}s ({:.1} ms/step)",
        out.steps,
        out.wall_secs,
        out.secs_per_step * 1e3
    );

    // 4. evaluate perplexity on held-out text.
    let eval = EvalSession::new(&rt, "lm", "tiny")?;
    let corpus = MarkovCorpus::generate(256, 4, 60_000, 42);
    let (mut nll, mut count) = (0.0, 0.0);
    for toks in corpus.test_batches(eval.batch, eval.seq).iter().take(8) {
        let o = eval.run(&trainer.sess.params, toks, &BatchExtra::None)?;
        nll += o.sum_nll;
        count += o.count;
    }
    let ppl = metrics::perplexity(nll, count);
    println!(
        "test perplexity {ppl:.2} (uniform would be 256, floor ≈ {:.1})",
        corpus.entropy_rate.exp()
    );

    // 5. checkpoint.
    checkpoint::save("results/quickstart.ckpt", &trainer.sess)?;
    println!("checkpoint saved to results/quickstart.ckpt");
    Ok(())
}
