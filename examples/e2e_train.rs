//! End-to-end driver: the full system on a real (synthetic-corpus)
//! language-modelling workload — the EXPERIMENTS.md §E2E run.
//!
//! Trains the `base` transformer (≈ 5.6 M parameters — the largest the
//! CPU-PJRT testbed trains in minutes; the same artifacts lower at any
//! size) for several hundred steps with all three optimizers through the
//! complete stack:
//!
//!   Rust data pipeline → PJRT-executed fused JAX train step (with the
//!   Pallas Alada kernels inside) → Rust metrics/checkpoints.
//!
//! Logs the loss curves to results/e2e_train.csv, reports test
//! perplexity and optimizer-state memory, and saves checkpoints.

use alada::data::MarkovCorpus;
use alada::optim::Schedule;
use alada::runtime::executor::{BatchExtra, EvalSession};
use alada::runtime::{Runtime, TrainSession};
use alada::train::{checkpoint, metrics, TaskData, Trainer};
use alada::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    alada::util::log::level_from_env();
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    let rt = Runtime::open("artifacts")?;
    let mut w = CsvWriter::create(
        "results/e2e_train.csv",
        &["optimizer", "step", "loss", "cum_avg_loss"],
    )?;

    println!("end-to-end: `base` LM ({} steps per optimizer; pass more on a bigger box)", steps);
    let mut summary = Vec::new();
    for opt in ["adam", "adafactor", "alada"] {
        let sess = TrainSession::new(&rt, "lm", "base", opt)?;
        let (batch, seq) = (sess.batch, sess.seq);
        let n_params = sess.params.len();
        let state_kib = sess.opt_state_bytes() / 1024;
        println!("\n[{opt}] {} params, optimizer state {} KiB", n_params, state_kib);

        let corpus = MarkovCorpus::generate(1024, 8, 400_000, 7);
        let floor = corpus.entropy_rate.exp();
        let data = TaskData::lm(corpus, batch, seq, 7);
        let lr = if opt == "adafactor" { 4e-3 } else { 2e-3 };
        let mut trainer =
            Trainer::new(sess, data, Schedule::Diminishing { eta0: lr, total: steps });
        trainer.record_every = (steps / 40).max(1);
        let out = trainer.run(steps)?;
        for (step, loss, avg) in &out.curve {
            w.row(&[opt.to_string(), step.to_string(), format!("{loss:.5}"), format!("{avg:.5}")])?;
        }

        // held-out perplexity
        let eval = EvalSession::new(&rt, "lm", "base")?;
        let corpus = MarkovCorpus::generate(1024, 8, 400_000, 7);
        let (mut nll, mut count) = (0.0, 0.0);
        for toks in corpus.test_batches(eval.batch, eval.seq).iter().take(12) {
            let o = eval.run(&trainer.sess.params, toks, &BatchExtra::None)?;
            nll += o.sum_nll;
            count += o.count;
        }
        let ppl = metrics::perplexity(nll, count);
        println!(
            "[{opt}] final cum-avg loss {:.4}, test ppl {:.2} (uniform 1024, floor ≈ {:.1}), {:.0} ms/step",
            out.final_cum_loss,
            ppl,
            floor,
            out.secs_per_step * 1e3
        );
        checkpoint::save(format!("results/e2e_{opt}.ckpt"), &trainer.sess)?;
        summary.push((opt, out.final_cum_loss, ppl, out.secs_per_step, state_kib));
    }
    w.flush()?;

    println!("\n=== e2e summary (see EXPERIMENTS.md §E2E) ===");
    println!(
        "{:<11}{:>14}{:>10}{:>12}{:>16}",
        "optimizer", "cum-avg loss", "ppl", "ms/step", "opt state KiB"
    );
    for (opt, loss, ppl, sps, kib) in summary {
        println!("{opt:<11}{loss:>14.4}{ppl:>10.2}{:>12.1}{kib:>16}", sps * 1e3);
    }
    println!("curves: results/e2e_train.csv; checkpoints: results/e2e_<opt>.ckpt");
    Ok(())
}
